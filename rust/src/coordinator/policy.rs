//! Online scheduling policy (§3.5): budget-feasible tier assignment with
//! hysteresis.
//!
//! Per layer, the target assignment is a *waterfill* down the precision
//! ladder: the hottest `n₀` experts sit at tier 0, the next `n₁` at tier 1,
//! and the rest at the base rung — budget-feasible by construction since
//! the per-rung capacities come from [`super::budget::BudgetPlan`].
//! [`plan_layer`] is the classic single-boundary (2-rung) rule;
//! [`plan_layer_ladder`] applies it per tier boundary (cumulative
//! capacities), so the 2-rung ladder reproduces it exactly. Two
//! refinements keep the transition rate predictable:
//!
//! * **idle experts are never promoted** (score ≤ 0 carries no traffic —
//!   promoting it wastes PCIe bandwidth for zero quality benefit);
//! * **hysteresis**: an outsider must beat the weakest resident by an
//!   additive margin *scaled by the mean resident score*. The paper allows
//!   an additive threshold or a rank slack; a purely relative margin is
//!   useless when the weakest resident's score has decayed to ≈ 0 (any
//!   candidate passes), which is exactly when churn storms start.

use std::collections::HashSet;

/// One layer's residency delta for the transition pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerPlan {
    pub promote: Vec<usize>,
    pub demote: Vec<usize>,
}

impl LayerPlan {
    pub fn is_empty(&self) -> bool {
        self.promote.is_empty() && self.demote.is_empty()
    }
}

/// NaN-proof score accessor: a NaN score is treated as idle (0 traffic),
/// so it can neither be promoted nor outrank a resident. Reachable NaN
/// sources (drift-triggered `stale_decay` rescaling of a degenerate EMA
/// state, a pathological user-supplied α) previously panicked the
/// planner's `partial_cmp(..).unwrap()` comparators; combined with
/// [`f64::total_cmp`] the planner now has a total order for any input.
#[inline]
fn score_of(scores: &[f64], e: usize) -> f64 {
    let s = scores[e];
    if s.is_nan() {
        0.0
    } else {
        s
    }
}

/// Reusable buffers for [`plan_layer_into`]. One instance amortizes every
/// per-call allocation of the planner (order/residents/members and the
/// hysteresis pairing lists) across the coordinator's per-layer loop —
/// `Coordinator::tick` plans all 48 logical layers with one scratch.
#[derive(Default)]
pub struct LayerScratch {
    order: Vec<usize>,
    residents: Vec<usize>,
    members: HashSet<usize>,
    sorted_members: Vec<usize>,
    outsiders: Vec<usize>,
    weak: Vec<usize>,
}

/// Compute the target delta for one layer.
///
/// * `scores` — smoothed hotness per expert
/// * `current` — experts currently hi-resident (or promoting)
/// * `n_hi` — budget-feasible capacity
/// * `margin` — hysteresis margin (fraction of the mean resident score;
///   0 disables hysteresis)
///
/// Swaps are paired strongest-candidate vs weakest-resident; a swap is
/// emitted only if `S[cand] > S[weak] + margin · mean(S[residents])`.
/// Capacity shrink (current > n_hi) demotes the weakest unconditionally.
///
/// Allocating convenience wrapper around [`plan_layer_into`] — identical
/// output by construction.
pub fn plan_layer(
    scores: &[f64],
    current: &HashSet<usize>,
    n_hi: usize,
    margin: f64,
) -> LayerPlan {
    let mut scratch = LayerScratch::default();
    let mut plan = LayerPlan::default();
    plan_layer_into(&mut scratch, scores, current, n_hi, margin, &mut plan);
    plan
}

/// [`plan_layer`] into caller-owned scratch and output buffers — the
/// allocation-free hot-path variant.
pub fn plan_layer_into(
    s: &mut LayerScratch,
    scores: &[f64],
    current: &HashSet<usize>,
    n_hi: usize,
    margin: f64,
    plan: &mut LayerPlan,
) {
    plan.promote.clear();
    plan.demote.clear();

    s.order.clear();
    s.order.extend(0..scores.len());
    s.order.sort_by(|&a, &b| {
        score_of(scores, b).total_cmp(&score_of(scores, a)).then(a.cmp(&b))
    });

    // Residents weakest-first for pairing.
    s.residents.clear();
    s.residents.extend(current.iter().copied());
    s.residents.sort_by(|&a, &b| {
        score_of(scores, a).total_cmp(&score_of(scores, b)).then(b.cmp(&a))
    });

    // Shrink to capacity first (eviction-priority under tight budget).
    let extra = s.residents.len().saturating_sub(n_hi);
    plan.demote.extend_from_slice(&s.residents[..extra]);
    let kept = &s.residents[extra..];

    // Fill spare capacity with the hottest *trafficked* outsiders.
    s.members.clear();
    s.members.extend(kept.iter().copied());
    for &e in &s.order {
        if s.members.len() >= n_hi {
            break;
        }
        if score_of(scores, e) <= 0.0 {
            break; // order is sorted: everything after is idle too
        }
        if !s.members.contains(&e) {
            s.members.insert(e);
            plan.promote.push(e);
        }
    }

    // Hysteresis swaps: strongest outsider vs weakest resident. The mean
    // is summed in index order — summing in HashSet iteration order would
    // make the float result (and thus, at the margin, the plan) depend on
    // the process-random hash seed, breaking byte-stable replay.
    let mean_resident = if s.members.is_empty() {
        0.0
    } else {
        s.sorted_members.clear();
        s.sorted_members.extend(s.members.iter().copied());
        s.sorted_members.sort_unstable();
        s.sorted_members.iter().map(|&e| score_of(scores, e)).sum::<f64>()
            / s.sorted_members.len() as f64
    };
    let threshold = margin * mean_resident;
    s.outsiders.clear();
    s.outsiders.extend(s.order.iter().copied().filter(|&e| {
        !s.members.contains(&e) && score_of(scores, e) > 0.0
    }));
    s.weak.clear();
    s.weak.extend(kept.iter().copied().filter(|e| s.members.contains(e)));
    let (mut oi, mut wi) = (0, 0);
    while oi < s.outsiders.len() && wi < s.weak.len() {
        let (cand, w) = (s.outsiders[oi], s.weak[wi]);
        if score_of(scores, cand)
            > score_of(scores, w) + threshold + f64::EPSILON
        {
            plan.promote.push(cand);
            plan.demote.push(w);
            oi += 1;
            wi += 1;
        } else {
            break;
        }
    }
}

/// One layer's tier-assignment delta for the transition pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LadderPlan {
    /// `(expert, target rung)` moves; downward moves (toward the base)
    /// first, so their evictions grow the feasible set for the upward ones.
    pub moves: Vec<(usize, usize)>,
}

impl LadderPlan {
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }
}

/// Compute the target tier assignment for one layer of an N-rung ladder.
///
/// * `scores` — smoothed hotness per expert
/// * `current_tier` — each expert's effective rung (published residency,
///   overridden by in-flight transition targets)
/// * `cum_caps` — cumulative per-layer capacities of the non-base rungs
///   (`N_t = Σ_{i≤t} n_i`, from
///   [`super::budget::BudgetPlan::cumulative_capacity`])
/// * `margin` — hysteresis margin, applied independently at every tier
///   boundary
///
/// Boundary `t` separates rungs `≤ t` from rungs `> t`; membership above
/// each boundary is planned with [`plan_layer`] on the cumulative capacity,
/// then nested (an expert above boundary `t` is above every deeper
/// boundary), so a 1-boundary ladder reproduces [`plan_layer`] exactly and
/// cumulative occupancy never exceeds `N_t` — which keeps any assignment
/// inside the byte envelope.
pub fn plan_layer_ladder(
    scores: &[f64],
    current_tier: &[usize],
    cum_caps: &[usize],
    margin: f64,
) -> LadderPlan {
    let mut scratch = LadderScratch::default();
    let mut plan = LadderPlan::default();
    plan_layer_ladder_into(
        &mut scratch,
        scores,
        current_tier,
        cum_caps,
        margin,
        &mut plan,
    );
    plan
}

/// Reusable buffers for [`plan_layer_ladder_into`]: the per-boundary
/// current/membership sets plus the inner [`LayerScratch`], reused across
/// every layer of a [`Coordinator::tick`] update.
///
/// [`Coordinator::tick`]: super::Coordinator::tick
#[derive(Default)]
pub struct LadderScratch {
    layer: LayerScratch,
    delta: LayerPlan,
    current: HashSet<usize>,
    memberships: Vec<HashSet<usize>>,
}

/// [`plan_layer_ladder`] into caller-owned scratch and output buffers —
/// the allocation-free variant the coordinator's update loop runs.
pub fn plan_layer_ladder_into(
    s: &mut LadderScratch,
    scores: &[f64],
    current_tier: &[usize],
    cum_caps: &[usize],
    margin: f64,
    plan: &mut LadderPlan,
) {
    debug_assert_eq!(scores.len(), current_tier.len());
    let n_boundaries = cum_caps.len();
    let base = n_boundaries;
    if s.memberships.len() < n_boundaries {
        s.memberships.resize_with(n_boundaries, HashSet::new);
    }
    for t in 0..n_boundaries {
        s.current.clear();
        s.current.extend(
            (0..current_tier.len()).filter(|&e| current_tier[e] <= t),
        );
        plan_layer_into(
            &mut s.layer,
            scores,
            &s.current,
            cum_caps[t],
            margin,
            &mut s.delta,
        );
        let (prevs, rest) = s.memberships.split_at_mut(t);
        let m = &mut rest[0];
        m.clear();
        m.extend(s.current.iter().copied());
        for &e in &s.delta.demote {
            m.remove(&e);
        }
        for &e in &s.delta.promote {
            m.insert(e);
        }
        if let Some(prev) = prevs.last() {
            // Nesting: whatever sits above a shallower boundary also sits
            // above this one; if the union overflows the cumulative cap,
            // the weakest non-nested members fall below this boundary.
            for &e in prev {
                m.insert(e);
            }
            while m.len() > cum_caps[t] {
                let weakest = m
                    .iter()
                    .copied()
                    .filter(|e| !prev.contains(e))
                    .min_by(|&a, &b| {
                        score_of(scores, a)
                            .total_cmp(&score_of(scores, b))
                            .then(b.cmp(&a))
                    });
                match weakest {
                    Some(e) => {
                        m.remove(&e);
                    }
                    None => break, // prev alone overflows — caps must grow
                }
            }
        }
    }
    let memberships = &s.memberships[..n_boundaries];
    let target = |e: usize| -> usize {
        memberships
            .iter()
            .position(|m| m.contains(&e))
            .unwrap_or(base)
    };
    // Downward moves first (their evictions grow the feasible set for the
    // upward ones), each group in expert-index order — the same order the
    // historical two-list construction produced.
    plan.moves.clear();
    for e in 0..scores.len() {
        let t = target(e);
        if t > current_tier[e] {
            plan.moves.push((e, t));
        }
    }
    for e in 0..scores.len() {
        let t = target(e);
        if t < current_tier[e] {
            plan.moves.push((e, t));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    fn set(xs: &[usize]) -> HashSet<usize> {
        xs.iter().copied().collect()
    }

    #[test]
    fn fills_empty_capacity_with_top_n() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[]), 2, 0.5);
        assert_eq!(p.promote, vec![2, 0]);
        assert!(p.demote.is_empty());
    }

    #[test]
    fn idle_experts_never_promoted() {
        let scores = [5.0, 0.0, 0.0, 0.0];
        let p = plan_layer(&scores, &set(&[]), 3, 0.0);
        assert_eq!(p.promote, vec![0], "zero-score experts stay cold");
    }

    #[test]
    fn stable_when_current_is_top_n() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.1);
        assert!(p.is_empty());
    }

    #[test]
    fn hysteresis_blocks_marginal_swap() {
        // residents {0, 2}: mean score 6 → threshold 1.2 at margin 0.2.
        // outsider 3 (4.0) vs weakest resident 0 (3.0): 4.0 < 4.2 blocked
        let scores = [3.0, 1.0, 9.0, 4.0];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.2);
        assert!(p.is_empty());
        // a clear winner (5.0 > 4.2) swaps
        let scores2 = [3.0, 1.0, 9.0, 5.0];
        let p2 = plan_layer(&scores2, &set(&[0, 2]), 2, 0.2);
        assert_eq!(p2.promote, vec![3]);
        assert_eq!(p2.demote, vec![0]);
    }

    #[test]
    fn zero_margin_is_plain_top_n() {
        let scores = [3.0, 1.0, 9.0, 3.1];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.0);
        assert_eq!(p.promote, vec![3]);
        assert_eq!(p.demote, vec![0]);
    }

    #[test]
    fn capacity_shrink_demotes_weakest() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[0, 2, 3]), 1, 0.0);
        assert_eq!(p.demote, vec![3, 0]); // weakest first
        assert!(p.promote.is_empty());
    }

    #[test]
    fn zero_capacity_demotes_all() {
        let scores = [5.0, 1.0];
        let p = plan_layer(&scores, &set(&[0, 1]), 0, 0.0);
        assert_eq!(p.demote.len(), 2);
        assert!(p.promote.is_empty());
    }

    #[test]
    fn prop_plan_respects_capacity_and_disjointness() {
        let mut prop = Prop::new("policy_capacity");
        prop.run(100, |rng| {
            let e = 4 + rng.below(60);
            let scores: Vec<f64> = (0..e).map(|_| rng.next_f64() * 10.0).collect();
            let n_hi = rng.below(e + 1);
            let mut current = HashSet::new();
            for i in 0..e {
                if rng.below(3) == 0 {
                    current.insert(i);
                }
            }
            let margin = rng.range_f64(0.0, 0.5);
            let p = plan_layer(&scores, &current, n_hi, margin);

            // promote/demote disjoint
            let ps: HashSet<_> = p.promote.iter().collect();
            let ds: HashSet<_> = p.demote.iter().collect();
            assert!(ps.is_disjoint(&ds));
            // promotions come from outside, demotions from inside
            for x in &p.promote {
                assert!(!current.contains(x));
                assert!(scores[*x] > 0.0, "idle experts never promoted");
            }
            for x in &p.demote {
                assert!(current.contains(x));
            }
            // the resulting set never exceeds capacity (unless it already
            // did — shrink handles that)
            let mut after = current.clone();
            for x in &p.demote {
                after.remove(x);
            }
            for x in &p.promote {
                after.insert(*x);
            }
            assert!(after.len() <= n_hi.max(current.len()));
        });
    }

    #[test]
    fn prop_zero_margin_selects_exact_top_n() {
        let mut prop = Prop::new("policy_topn_exact");
        prop.run(50, |rng| {
            let e = 4 + rng.below(40);
            // distinct positive scores (idle-skip rule needs > 0)
            let mut scores: Vec<f64> = (1..=e).map(|i| i as f64).collect();
            rng.shuffle(&mut scores);
            let n_hi = rng.below(e + 1);
            let mut current = HashSet::new();
            for i in 0..e {
                if rng.below(2) == 0 {
                    current.insert(i);
                }
            }
            let p = plan_layer(&scores, &current, n_hi, 0.0);
            let mut after = current.clone();
            for x in &p.demote {
                after.remove(x);
            }
            for x in &p.promote {
                after.insert(*x);
            }
            // after == true top-n
            let mut idx: Vec<usize> = (0..e).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let want: HashSet<usize> = idx[..n_hi].iter().copied().collect();
            assert_eq!(after, want);
        });
    }

    #[test]
    fn nan_and_infinite_scores_never_panic() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN scores
        // (reachable through drift-triggered stale_decay rescaling of a
        // degenerate EMA state). NaN now totals-orders as idle: the plan
        // is well-defined and NaN-scored experts are never promoted.
        let scores = [
            f64::NAN,
            5.0,
            f64::INFINITY,
            -1.0,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        let p = plan_layer(&scores, &set(&[0, 3]), 2, 0.2);
        for &e in &p.promote {
            assert!(scores[e] > 0.0, "NaN/idle expert {e} promoted");
        }
        // the clear winners displace the NaN/negative residents
        assert_eq!(p.promote, vec![2, 1]);
        assert_eq!(p.demote, vec![3, 0]);

        // the ladder planner hits the same comparators via tick
        let current = [1usize; 6];
        let lp = plan_layer_ladder(&scores, &current, &[2], 0.2);
        for &(e, t) in &lp.moves {
            if t == 0 {
                assert!(scores[e] > 0.0, "NaN expert {e} moved up");
            }
        }

        // an all-NaN layer is inert, not a crash
        let all_nan = [f64::NAN; 4];
        let p = plan_layer(&all_nan, &set(&[1]), 2, 0.0);
        assert!(p.is_empty(), "{p:?}");
        let lp = plan_layer_ladder(&all_nan, &[1, 1, 1, 1], &[2], 0.0);
        assert!(lp.is_empty(), "{lp:?}");
    }

    #[test]
    fn prop_scratch_reuse_matches_fresh_allocation() {
        // One LadderScratch reused across many random layers (the
        // coordinator's update loop shape) must produce exactly the plans
        // a fresh allocation per call produces — no state leaks between
        // calls.
        let mut prop = Prop::new("policy_scratch_reuse");
        let mut scratch = LadderScratch::default();
        let mut plan = LadderPlan::default();
        prop.run(60, |rng| {
            let e = 4 + rng.below(40);
            let scores: Vec<f64> =
                (0..e).map(|_| rng.next_f64() * 10.0).collect();
            let n_tiers = 2 + rng.below(2);
            let mut cum_caps = Vec::new();
            let mut cum = 0;
            for _ in 0..n_tiers - 1 {
                cum += rng.below(e / 2 + 1);
                cum_caps.push(cum.min(e));
            }
            let current: Vec<usize> =
                (0..e).map(|_| rng.below(n_tiers)).collect();
            let margin = rng.range_f64(0.0, 0.4);
            let fresh = plan_layer_ladder(&scores, &current, &cum_caps, margin);
            plan_layer_ladder_into(
                &mut scratch,
                &scores,
                &current,
                &cum_caps,
                margin,
                &mut plan,
            );
            assert_eq!(fresh, plan);
        });
    }

    /// Apply a ladder plan to a tier assignment.
    fn apply(current: &[usize], plan: &LadderPlan) -> Vec<usize> {
        let mut out = current.to_vec();
        for &(e, t) in &plan.moves {
            out[e] = t;
        }
        out
    }

    #[test]
    fn ladder_waterfill_assigns_by_hotness() {
        // capacities: 1 at tier 0, 2 more at tier 1 (cum [1, 3])
        let scores = [5.0, 9.0, 1.0, 3.0, 0.0];
        let current = [2usize; 5];
        let p = plan_layer_ladder(&scores, &current, &[1, 3], 0.0);
        let after = apply(&current, &p);
        assert_eq!(after, vec![1, 0, 2, 1, 2]);
    }

    #[test]
    fn ladder_downward_moves_precede_upward() {
        let scores = [1.0, 9.0];
        let current = [0usize, 2];
        let p = plan_layer_ladder(&scores, &current, &[1, 2], 0.0);
        assert_eq!(p.moves.len(), 2);
        assert!(p.moves[0].1 > current[p.moves[0].0], "demotion first");
        assert_eq!(p.moves[1], (1, 0));
    }

    #[test]
    fn prop_two_rung_ladder_reproduces_plan_layer_exactly() {
        // Satellite (b): the degenerate 2-rung ladder must emit the same
        // promote/demote sets as the classic planner, for any input.
        let mut prop = Prop::new("ladder_two_rung_equiv");
        prop.run(100, |rng| {
            let e = 4 + rng.below(60);
            let scores: Vec<f64> =
                (0..e).map(|_| rng.next_f64() * 10.0).collect();
            let n_hi = rng.below(e + 1);
            let margin = rng.range_f64(0.0, 0.5);
            let current_tier: Vec<usize> =
                (0..e).map(|_| rng.below(2)).collect();
            let current: HashSet<usize> = (0..e)
                .filter(|&i| current_tier[i] == 0)
                .collect();
            let classic = plan_layer(&scores, &current, n_hi, margin);
            let ladder =
                plan_layer_ladder(&scores, &current_tier, &[n_hi], margin);
            let promote: HashSet<usize> = ladder
                .moves
                .iter()
                .filter(|&&(_, t)| t == 0)
                .map(|&(e, _)| e)
                .collect();
            let demote: HashSet<usize> = ladder
                .moves
                .iter()
                .filter(|&&(_, t)| t == 1)
                .map(|&(e, _)| e)
                .collect();
            let classic_p: HashSet<usize> =
                classic.promote.iter().copied().collect();
            let classic_d: HashSet<usize> =
                classic.demote.iter().copied().collect();
            assert_eq!(promote, classic_p);
            assert_eq!(demote, classic_d);
        });
    }

    #[test]
    fn prop_ladder_waterfill_monotone_in_hotness() {
        // Satellite (a): with hysteresis disabled, a hotter expert never
        // sits at a lower (colder) rung than a colder trafficked one.
        let mut prop = Prop::new("ladder_monotone");
        prop.run(100, |rng| {
            let e = 4 + rng.below(40);
            // distinct positive scores so the waterfill is unambiguous
            let mut scores: Vec<f64> = (1..=e).map(|i| i as f64).collect();
            rng.shuffle(&mut scores);
            let n_tiers = 2 + rng.below(2); // 2 or 3 rungs
            let mut cum_caps = Vec::new();
            let mut cum = 0;
            for _ in 0..n_tiers - 1 {
                cum += rng.below(e / 2 + 1);
                cum_caps.push(cum.min(e));
            }
            let current: Vec<usize> =
                (0..e).map(|_| rng.below(n_tiers)).collect();
            let p = plan_layer_ladder(&scores, &current, &cum_caps, 0.0);
            let after = apply(&current, &p);
            for a in 0..e {
                for b in 0..e {
                    if scores[a] > scores[b] {
                        assert!(
                            after[a] <= after[b],
                            "hotter expert {a} (S={}) at rung {} below \
                             colder {b} (S={}) at rung {}",
                            scores[a],
                            after[a],
                            scores[b],
                            after[b]
                        );
                    }
                }
            }
            // cumulative occupancy never exceeds cumulative capacity
            for (t, &cap) in cum_caps.iter().enumerate() {
                let occ = after.iter().filter(|&&x| x <= t).count();
                assert!(occ <= cap, "boundary {t}: {occ} > {cap}");
            }
        });
    }

    #[test]
    fn prop_ladder_moves_are_consistent() {
        // Moves only name experts whose rung actually changes, downward
        // moves come first, and targets are on the ladder.
        let mut prop = Prop::new("ladder_moves_consistent");
        prop.run(60, |rng| {
            let e = 4 + rng.below(40);
            let scores: Vec<f64> =
                (0..e).map(|_| rng.next_f64() * 10.0).collect();
            let n_tiers = 2 + rng.below(3);
            let mut cum_caps = Vec::new();
            let mut cum = 0;
            for _ in 0..n_tiers - 1 {
                cum += rng.below(e / 2 + 1);
                cum_caps.push(cum.min(e));
            }
            let current: Vec<usize> =
                (0..e).map(|_| rng.below(n_tiers)).collect();
            let margin = rng.range_f64(0.0, 0.4);
            let p = plan_layer_ladder(&scores, &current, &cum_caps, margin);
            let mut seen_up = false;
            for &(ex, t) in &p.moves {
                assert!(t < n_tiers);
                assert_ne!(t, current[ex], "no-op move emitted");
                if t < current[ex] {
                    seen_up = true;
                    assert!(
                        scores[ex] > 0.0,
                        "idle experts never move up the ladder"
                    );
                } else {
                    assert!(!seen_up, "downward move after an upward one");
                }
            }
        });
    }

    #[test]
    fn prop_hysteresis_reduces_churn() {
        // With noisy scores around a boundary, margin > 0 must produce
        // fewer cumulative transitions than margin = 0.
        let mut prop = Prop::new("policy_churn");
        prop.run(20, |rng| {
            let e = 16;
            let n_hi = 4;
            let base: Vec<f64> = (0..e).map(|i| 10.0 - i as f64 * 0.1).collect();
            let mut cur0: HashSet<usize> = (0..n_hi).collect();
            let mut cur1: HashSet<usize> = (0..n_hi).collect();
            let mut churn0 = 0;
            let mut churn1 = 0;
            for _ in 0..50 {
                let noisy: Vec<f64> = base
                    .iter()
                    .map(|b| (b + rng.normal() * 0.3).max(0.01))
                    .collect();
                let p0 = plan_layer(&noisy, &cur0, n_hi, 0.0);
                let p1 = plan_layer(&noisy, &cur1, n_hi, 0.3);
                churn0 += p0.promote.len();
                churn1 += p1.promote.len();
                for x in &p0.demote {
                    cur0.remove(x);
                }
                cur0.extend(&p0.promote);
                for x in &p1.demote {
                    cur1.remove(x);
                }
                cur1.extend(&p1.promote);
            }
            assert!(
                churn1 <= churn0,
                "hysteresis churn {churn1} > plain {churn0}"
            );
        });
    }
}
