//! Online scheduling policy (§3.5): budget-feasible top-n selection with
//! hysteresis.
//!
//! Per layer, the target high-precision set is the top-`n_hi` experts by
//! smoothed hotness — budget-feasible by construction since `n_hi` comes
//! from [`super::budget::BudgetPlan`]. Two refinements keep the transition
//! rate predictable:
//!
//! * **idle experts are never promoted** (score ≤ 0 carries no traffic —
//!   promoting it wastes PCIe bandwidth for zero quality benefit);
//! * **hysteresis**: an outsider must beat the weakest resident by an
//!   additive margin *scaled by the mean resident score*. The paper allows
//!   an additive threshold or a rank slack; a purely relative margin is
//!   useless when the weakest resident's score has decayed to ≈ 0 (any
//!   candidate passes), which is exactly when churn storms start.

use std::collections::HashSet;

/// One layer's residency delta for the transition pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerPlan {
    pub promote: Vec<usize>,
    pub demote: Vec<usize>,
}

impl LayerPlan {
    pub fn is_empty(&self) -> bool {
        self.promote.is_empty() && self.demote.is_empty()
    }
}

/// Compute the target delta for one layer.
///
/// * `scores` — smoothed hotness per expert
/// * `current` — experts currently hi-resident (or promoting)
/// * `n_hi` — budget-feasible capacity
/// * `margin` — hysteresis margin (fraction of the mean resident score;
///   0 disables hysteresis)
///
/// Swaps are paired strongest-candidate vs weakest-resident; a swap is
/// emitted only if `S[cand] > S[weak] + margin · mean(S[residents])`.
/// Capacity shrink (current > n_hi) demotes the weakest unconditionally.
pub fn plan_layer(
    scores: &[f64],
    current: &HashSet<usize>,
    n_hi: usize,
    margin: f64,
) -> LayerPlan {
    let mut plan = LayerPlan::default();
    let order = {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
        });
        idx
    };

    // Residents weakest-first for pairing.
    let mut residents: Vec<usize> = current.iter().copied().collect();
    residents.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap().then(b.cmp(&a))
    });

    // Shrink to capacity first (eviction-priority under tight budget).
    while residents.len() > n_hi {
        let weakest = residents.remove(0);
        plan.demote.push(weakest);
    }

    // Fill spare capacity with the hottest *trafficked* outsiders.
    let mut members: HashSet<usize> = residents.iter().copied().collect();
    for &e in &order {
        if members.len() >= n_hi {
            break;
        }
        if scores[e] <= 0.0 {
            break; // order is sorted: everything after is idle too
        }
        if !members.contains(&e) {
            members.insert(e);
            plan.promote.push(e);
        }
    }

    // Hysteresis swaps: strongest outsider vs weakest resident.
    let mean_resident = if members.is_empty() {
        0.0
    } else {
        members.iter().map(|&e| scores[e]).sum::<f64>() / members.len() as f64
    };
    let threshold = margin * mean_resident;
    let mut out: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&e| !members.contains(&e) && scores[e] > 0.0)
        .collect();
    let mut weak: Vec<usize> = residents
        .iter()
        .copied()
        .filter(|e| members.contains(e))
        .collect();
    while let (Some(&cand), Some(&w)) = (out.first(), weak.first()) {
        if scores[cand] > scores[w] + threshold + f64::EPSILON {
            plan.promote.push(cand);
            plan.demote.push(w);
            out.remove(0);
            weak.remove(0);
        } else {
            break;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    fn set(xs: &[usize]) -> HashSet<usize> {
        xs.iter().copied().collect()
    }

    #[test]
    fn fills_empty_capacity_with_top_n() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[]), 2, 0.5);
        assert_eq!(p.promote, vec![2, 0]);
        assert!(p.demote.is_empty());
    }

    #[test]
    fn idle_experts_never_promoted() {
        let scores = [5.0, 0.0, 0.0, 0.0];
        let p = plan_layer(&scores, &set(&[]), 3, 0.0);
        assert_eq!(p.promote, vec![0], "zero-score experts stay cold");
    }

    #[test]
    fn stable_when_current_is_top_n() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.1);
        assert!(p.is_empty());
    }

    #[test]
    fn hysteresis_blocks_marginal_swap() {
        // residents {0, 2}: mean score 6 → threshold 1.2 at margin 0.2.
        // outsider 3 (4.0) vs weakest resident 0 (3.0): 4.0 < 4.2 blocked
        let scores = [3.0, 1.0, 9.0, 4.0];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.2);
        assert!(p.is_empty());
        // a clear winner (5.0 > 4.2) swaps
        let scores2 = [3.0, 1.0, 9.0, 5.0];
        let p2 = plan_layer(&scores2, &set(&[0, 2]), 2, 0.2);
        assert_eq!(p2.promote, vec![3]);
        assert_eq!(p2.demote, vec![0]);
    }

    #[test]
    fn zero_margin_is_plain_top_n() {
        let scores = [3.0, 1.0, 9.0, 3.1];
        let p = plan_layer(&scores, &set(&[0, 2]), 2, 0.0);
        assert_eq!(p.promote, vec![3]);
        assert_eq!(p.demote, vec![0]);
    }

    #[test]
    fn capacity_shrink_demotes_weakest() {
        let scores = [5.0, 1.0, 9.0, 3.0];
        let p = plan_layer(&scores, &set(&[0, 2, 3]), 1, 0.0);
        assert_eq!(p.demote, vec![3, 0]); // weakest first
        assert!(p.promote.is_empty());
    }

    #[test]
    fn zero_capacity_demotes_all() {
        let scores = [5.0, 1.0];
        let p = plan_layer(&scores, &set(&[0, 1]), 0, 0.0);
        assert_eq!(p.demote.len(), 2);
        assert!(p.promote.is_empty());
    }

    #[test]
    fn prop_plan_respects_capacity_and_disjointness() {
        let mut prop = Prop::new("policy_capacity");
        prop.run(100, |rng| {
            let e = 4 + rng.below(60);
            let scores: Vec<f64> = (0..e).map(|_| rng.next_f64() * 10.0).collect();
            let n_hi = rng.below(e + 1);
            let mut current = HashSet::new();
            for i in 0..e {
                if rng.below(3) == 0 {
                    current.insert(i);
                }
            }
            let margin = rng.range_f64(0.0, 0.5);
            let p = plan_layer(&scores, &current, n_hi, margin);

            // promote/demote disjoint
            let ps: HashSet<_> = p.promote.iter().collect();
            let ds: HashSet<_> = p.demote.iter().collect();
            assert!(ps.is_disjoint(&ds));
            // promotions come from outside, demotions from inside
            for x in &p.promote {
                assert!(!current.contains(x));
                assert!(scores[*x] > 0.0, "idle experts never promoted");
            }
            for x in &p.demote {
                assert!(current.contains(x));
            }
            // the resulting set never exceeds capacity (unless it already
            // did — shrink handles that)
            let mut after = current.clone();
            for x in &p.demote {
                after.remove(x);
            }
            for x in &p.promote {
                after.insert(*x);
            }
            assert!(after.len() <= n_hi.max(current.len()));
        });
    }

    #[test]
    fn prop_zero_margin_selects_exact_top_n() {
        let mut prop = Prop::new("policy_topn_exact");
        prop.run(50, |rng| {
            let e = 4 + rng.below(40);
            // distinct positive scores (idle-skip rule needs > 0)
            let mut scores: Vec<f64> = (1..=e).map(|i| i as f64).collect();
            rng.shuffle(&mut scores);
            let n_hi = rng.below(e + 1);
            let mut current = HashSet::new();
            for i in 0..e {
                if rng.below(2) == 0 {
                    current.insert(i);
                }
            }
            let p = plan_layer(&scores, &current, n_hi, 0.0);
            let mut after = current.clone();
            for x in &p.demote {
                after.remove(x);
            }
            for x in &p.promote {
                after.insert(*x);
            }
            // after == true top-n
            let mut idx: Vec<usize> = (0..e).collect();
            idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let want: HashSet<usize> = idx[..n_hi].iter().copied().collect();
            assert_eq!(after, want);
        });
    }

    #[test]
    fn prop_hysteresis_reduces_churn() {
        // With noisy scores around a boundary, margin > 0 must produce
        // fewer cumulative transitions than margin = 0.
        let mut prop = Prop::new("policy_churn");
        prop.run(20, |rng| {
            let e = 16;
            let n_hi = 4;
            let base: Vec<f64> = (0..e).map(|i| 10.0 - i as f64 * 0.1).collect();
            let mut cur0: HashSet<usize> = (0..n_hi).collect();
            let mut cur1: HashSet<usize> = (0..n_hi).collect();
            let mut churn0 = 0;
            let mut churn1 = 0;
            for _ in 0..50 {
                let noisy: Vec<f64> = base
                    .iter()
                    .map(|b| (b + rng.normal() * 0.3).max(0.01))
                    .collect();
                let p0 = plan_layer(&noisy, &cur0, n_hi, 0.0);
                let p1 = plan_layer(&noisy, &cur1, n_hi, 0.3);
                churn0 += p0.promote.len();
                churn1 += p1.promote.len();
                for x in &p0.demote {
                    cur0.remove(x);
                }
                cur0.extend(&p0.promote);
                for x in &p1.demote {
                    cur1.remove(x);
                }
                cur1.extend(&p1.promote);
            }
            assert!(
                churn1 <= churn0,
                "hysteresis churn {churn1} > plain {churn0}"
            );
        });
    }
}
