//! Budget model and OOM safety (§3.3), per ladder rung.
//!
//! A [`BudgetTracker`] enforces the hard HBM envelope: `M_total` usable
//! bytes, `M_fixed` reserved for non-expert state (KV cache, activations,
//! runtime), and the remainder split between the expert-residency rungs of
//! the precision ladder. Every upward transition must pass `try_reserve`
//! **before** entering the transition pipeline; a successful reservation
//! guarantees the subsequent pool allocation cannot OOM. Reservation and
//! release are atomic (CAS loops) so the migration worker and the policy
//! thread never race the envelope.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::ModelPreset;
use crate::model::{expert_bytes, Precision, PrecisionLadder};

/// Atomic per-rung byte-budget tracker with explicit reserve/release.
#[derive(Debug)]
pub struct BudgetTracker {
    /// Byte cap per rung (tier 0 first; the base rung's cap covers the
    /// statically provisioned all-cold residency).
    caps: Vec<usize>,
    used: Vec<AtomicUsize>,
    /// Diagnostics.
    pub failed_reservations: AtomicUsize,
}

impl BudgetTracker {
    /// Per-rung caps, tier 0 first.
    pub fn with_caps(caps: Vec<usize>) -> Self {
        let used = caps.iter().map(|_| AtomicUsize::new(0)).collect();
        Self { caps, used, failed_reservations: AtomicUsize::new(0) }
    }

    /// Two-rung convenience (the classic hi/lo tracker).
    pub fn new(hi_cap: usize, lo_cap: usize) -> Self {
        Self::with_caps(vec![hi_cap, lo_cap])
    }

    pub fn n_tiers(&self) -> usize {
        self.caps.len()
    }

    /// Reserve `bytes` of rung `tier` capacity; false if it would exceed
    /// the cap (the transition must then be deferred — never forced).
    pub fn try_reserve(&self, tier: usize, bytes: usize) -> bool {
        let used = &self.used[tier];
        let cap = self.caps[tier];
        let mut cur = used.load(Ordering::Relaxed); // relaxed-ok: CAS loop seed, retried on mismatch
        loop {
            if cur + bytes > cap {
                self.failed_reservations.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                return false;
            }
            match used.compare_exchange_weak(
                cur,
                cur + bytes,
                Ordering::AcqRel,
                Ordering::Relaxed, // relaxed-ok: CAS failure path just reloads
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Release previously reserved bytes of rung `tier`.
    pub fn release(&self, tier: usize, bytes: usize) {
        let prev = self.used[tier].fetch_sub(bytes, Ordering::AcqRel);
        debug_assert!(prev >= bytes, "release underflow at tier {tier}");
    }

    pub fn used(&self, tier: usize) -> usize {
        self.used[tier].load(Ordering::Relaxed) // relaxed-ok: advisory usage read
    }

    pub fn cap(&self, tier: usize) -> usize {
        self.caps[tier]
    }

    /// Top-rung convenience accessors (diagnostics/tests).
    pub fn try_reserve_hi(&self, bytes: usize) -> bool {
        self.try_reserve(0, bytes)
    }

    pub fn release_hi(&self, bytes: usize) {
        self.release(0, bytes)
    }

    pub fn hi_used(&self) -> usize {
        self.used(0)
    }

    pub fn hi_cap(&self) -> usize {
        self.cap(0)
    }

    /// Base-rung convenience accessors.
    pub fn try_reserve_lo(&self, bytes: usize) -> bool {
        self.try_reserve(self.caps.len() - 1, bytes)
    }

    pub fn lo_used(&self) -> usize {
        self.used(self.caps.len() - 1)
    }

    pub fn lo_cap(&self) -> usize {
        self.cap(self.caps.len() - 1)
    }

    /// Invariant check (used by tests and debug assertions): every rung
    /// within its cap.
    pub fn within_envelope(&self) -> bool {
        (0..self.caps.len()).all(|t| self.used(t) <= self.caps[t])
    }
}

/// Budget initialization (§3.1), generalized to the ladder: derive the
/// per-layer capacity of every non-base rung from the envelope by
/// waterfill.
///
/// Feasibility by construction: with the base rung statically provisioned
/// (`fixed + shared + layers·E·B_base`), the remaining slack is split
/// across the non-base rungs; rung `t` affords
/// `slack_t / (layers·(B_t − B_base))` experts per layer, since raising an
/// expert to rung `t` frees its base copy. The policy only ever assigns at
/// most `Σ_{i≤t} n_i` experts to rungs `≤ t` per layer (cumulative
/// capacity), which keeps total bytes inside the envelope for any
/// assignment (Abel summation over the strictly decreasing rung sizes).
#[derive(Clone, Debug)]
pub struct BudgetPlan {
    /// Per-layer expert capacity of each non-base rung (tier 0 first).
    pub tier_capacity: Vec<usize>,
    /// Byte cap of each rung's pool (tier 0 .. base).
    pub pool_bytes: Vec<usize>,
    /// Bytes of one expert at each rung.
    pub tier_expert_bytes: Vec<usize>,
}

impl BudgetPlan {
    /// Compute the plan for `preset` under `(total, fixed)` bytes at
    /// *executed* scale (uses the crate's small-model expert bytes).
    ///
    /// Returns an error if even all-cold residency does not fit — the
    /// envelope is then infeasible for this model (the paper's systems
    /// would refuse to start).
    pub fn derive(
        preset: &ModelPreset,
        total_bytes: usize,
        fixed_bytes: usize,
    ) -> Result<Self, String> {
        Self::derive_with(
            &preset.ladder,
            expert_bytes,
            preset.n_layers,
            preset.n_experts,
            preset.n_shared,
            total_bytes,
            fixed_bytes,
            None,
        )
    }

    /// The shared derivation: `bytes_of` supplies per-rung expert bytes at
    /// whichever scale the caller plans at (logical for the coordinator,
    /// executed for [`BudgetPlan::derive`]). `n_hi_override` forces the
    /// top rung's capacity and is validated against the envelope.
    #[allow(clippy::too_many_arguments)]
    pub fn derive_with(
        ladder: &PrecisionLadder,
        bytes_of: impl Fn(Precision) -> usize,
        layers: usize,
        n_experts: usize,
        n_shared: usize,
        total_bytes: usize,
        fixed_bytes: usize,
        n_hi_override: Option<usize>,
    ) -> Result<Self, String> {
        let b: Vec<usize> = ladder.tiers().iter().map(|&p| bytes_of(p)).collect();
        let base = ladder.base_tier();
        let b_base = b[base];
        // Shared experts are pinned at the top rung, always resident.
        let shared = layers * n_shared * b[0];
        let baseline = fixed_bytes + shared + layers * n_experts * b_base;
        if baseline > total_bytes {
            return Err(format!(
                "infeasible envelope: all-cold residency needs {baseline} \
                 bytes but budget is {total_bytes}"
            ));
        }
        let slack = total_bytes - baseline;
        let n_nonbase = base; // rungs above the base
        let mut tier_capacity = vec![0usize; n_nonbase];
        if n_nonbase > 0 {
            // Raising one expert to rung t frees its base copy, so the
            // upgrade cost is the byte *difference*. A degenerate ladder
            // (adjacent rungs byte-identical) would divide by zero here.
            let mut cost = Vec::with_capacity(n_nonbase);
            for (t, &bytes) in b.iter().enumerate().take(n_nonbase) {
                if bytes <= b_base {
                    return Err(format!(
                        "degenerate ladder: rung {t} ({:?}, {bytes} B) is \
                         not larger than the base rung ({:?}, {b_base} B)",
                        ladder.tier(t),
                        ladder.base(),
                    ));
                }
                cost.push(bytes - b_base);
            }
            match n_hi_override {
                Some(n0) => {
                    let n0 = n0.min(n_experts);
                    let cost0 = layers * n0 * cost[0];
                    if cost0 > slack {
                        return Err(format!(
                            "n_hi_override={n0} overcommits the envelope: \
                             the top rung needs {cost0} B of slack but only \
                             {slack} B remain (short by {} B; max feasible \
                             override is {})",
                            cost0 - slack,
                            slack / (layers * cost[0]),
                        ));
                    }
                    tier_capacity[0] = n0;
                    // Remaining non-base rungs split the leftover equally.
                    let rest = slack - cost0;
                    for t in 1..n_nonbase {
                        tier_capacity[t] =
                            (rest / (n_nonbase - 1)) / (layers * cost[t]);
                    }
                }
                None => {
                    // Waterfill: each non-base rung gets an equal byte
                    // share of the slack (the 2-rung ladder degenerates to
                    // the original `slack / (layers·(B_hi − B_lo))`).
                    for t in 0..n_nonbase {
                        tier_capacity[t] =
                            (slack / n_nonbase) / (layers * cost[t]);
                    }
                }
            }
            // Cumulative clamp: rungs cannot jointly hold more experts
            // than exist.
            let mut cum = 0usize;
            for cap in tier_capacity.iter_mut() {
                *cap = (*cap).min(n_experts - cum);
                cum += *cap;
            }
        }
        // Pools are sized at *cumulative* capacity per rung: the planner
        // may park up to N_t experts at rungs ≤ t, and any such assignment
        // stays inside the envelope because rung bytes strictly decrease.
        let mut pool_bytes = Vec::with_capacity(b.len());
        let mut cum = 0usize;
        for (t, &bytes) in b.iter().enumerate() {
            if t == base {
                pool_bytes.push(layers * n_experts * b_base);
            } else {
                cum += tier_capacity[t];
                let shared_slots = if t == 0 { n_shared } else { 0 };
                pool_bytes.push(layers * (cum + shared_slots) * bytes);
            }
        }
        Ok(Self { tier_capacity, pool_bytes, tier_expert_bytes: b })
    }

    pub fn n_tiers(&self) -> usize {
        self.tier_expert_bytes.len()
    }

    /// Per-layer capacity of the top rung (the classic `n_hi`).
    pub fn n_hi_per_layer(&self) -> usize {
        self.tier_capacity.first().copied().unwrap_or(0)
    }

    /// Cumulative per-layer capacities over the non-base rungs
    /// (`N_t = Σ_{i≤t} n_i`) — the policy's boundary budgets.
    pub fn cumulative_capacity(&self) -> Vec<usize> {
        let mut cum = 0usize;
        self.tier_capacity
            .iter()
            .map(|&n| {
                cum += n;
                cum
            })
            .collect()
    }

    pub fn hi_expert_bytes(&self) -> usize {
        self.tier_expert_bytes[0]
    }

    pub fn lo_expert_bytes(&self) -> usize {
        *self.tier_expert_bytes.last().unwrap()
    }

    pub fn hi_pool_bytes(&self) -> usize {
        self.pool_bytes[0]
    }

    pub fn lo_pool_bytes(&self) -> usize {
        *self.pool_bytes.last().unwrap()
    }

    /// Fraction of experts resident at the top rung.
    pub fn hot_fraction(&self, preset: &ModelPreset) -> f64 {
        self.n_hi_per_layer() as f64 / preset.n_experts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn reserve_release_roundtrip() {
        let b = BudgetTracker::new(100, 50);
        assert!(b.try_reserve_hi(60));
        assert!(!b.try_reserve_hi(41));
        assert!(b.try_reserve_hi(40));
        b.release_hi(60);
        assert_eq!(b.hi_used(), 40);
        assert!(b.within_envelope());
        assert_eq!(b.failed_reservations.load(Ordering::Relaxed), 1); // relaxed-ok: test assertion
    }

    #[test]
    fn per_tier_accounting_is_independent() {
        let b = BudgetTracker::with_caps(vec![100, 50, 1000]);
        assert_eq!(b.n_tiers(), 3);
        assert!(b.try_reserve(1, 50));
        assert!(!b.try_reserve(1, 1));
        assert!(b.try_reserve(0, 100));
        assert!(b.try_reserve(2, 400));
        assert_eq!(b.used(1), 50);
        b.release(1, 50);
        assert_eq!(b.used(1), 0);
        assert_eq!(b.hi_used(), 100);
        assert_eq!(b.lo_used(), 400);
        assert!(b.within_envelope());
    }

    #[test]
    fn plan_feasible_by_construction() {
        let preset = ModelPreset::qwen30b_sim();
        // scaled-down envelope sized against the *small* executed model
        let total = 20 << 20;
        let fixed = 8 << 20;
        let plan = BudgetPlan::derive(&preset, total, fixed).unwrap();
        let b_hi = plan.hi_expert_bytes();
        let b_lo = plan.lo_expert_bytes();
        let n_hi = plan.n_hi_per_layer();
        let used = fixed
            + preset.n_layers
                * (n_hi * b_hi + (preset.n_experts - n_hi) * b_lo);
        assert!(used <= total, "plan must fit: {used} > {total}");
        assert!(n_hi > 0);
        assert!(n_hi < preset.n_experts);
    }

    #[test]
    fn plan_rejects_infeasible() {
        let preset = ModelPreset::qwen30b_sim();
        assert!(BudgetPlan::derive(&preset, 1 << 20, 1 << 19).is_err());
    }

    #[test]
    fn tighter_budget_fewer_hot_experts() {
        let preset = ModelPreset::qwen30b_sim();
        let p1 = BudgetPlan::derive(&preset, 20 << 20, 8 << 20).unwrap();
        let p2 = BudgetPlan::derive(&preset, 17 << 20, 8 << 20).unwrap();
        assert!(p2.n_hi_per_layer() < p1.n_hi_per_layer());
    }

    #[test]
    fn shared_experts_accounted() {
        let mut p80 = ModelPreset::qwen80b_sim();
        p80.n_layers = 2;
        let plan = BudgetPlan::derive(&p80, 64 << 20, 4 << 20).unwrap();
        // top-rung pool must have room for shared experts even at n_hi = 0
        assert!(
            plan.hi_pool_bytes()
                >= p80.n_layers * p80.n_shared * plan.hi_expert_bytes()
        );
    }

    #[test]
    fn three_rung_plan_funds_every_rung_within_envelope() {
        let preset = ModelPreset::qwen30b_3tier();
        let total = 24 << 20;
        let fixed = 8 << 20;
        let plan = BudgetPlan::derive(&preset, total, fixed).unwrap();
        assert_eq!(plan.n_tiers(), 3);
        assert_eq!(plan.tier_capacity.len(), 2);
        assert!(plan.tier_capacity[0] > 0, "fp16 rung funded");
        assert!(plan.tier_capacity[1] > 0, "int4 rung funded");
        // worst case: every cumulative slot filled at its own rung
        let cum = plan.cumulative_capacity();
        let worst = fixed
            + preset.n_layers
                * (plan.tier_capacity[0] * plan.tier_expert_bytes[0]
                    + plan.tier_capacity[1] * plan.tier_expert_bytes[1]
                    + (preset.n_experts - cum[1])
                        * plan.tier_expert_bytes[2]);
        assert!(worst <= total, "waterfill must fit: {worst} > {total}");
    }

    #[test]
    fn override_overcommit_rejected_with_shortfall() {
        let preset = ModelPreset::qwen30b_sim();
        let err = BudgetPlan::derive_with(
            &preset.ladder,
            expert_bytes,
            preset.n_layers,
            preset.n_experts,
            preset.n_shared,
            20 << 20,
            8 << 20,
            Some(preset.n_experts),
        )
        .unwrap_err();
        assert!(err.contains("overcommits"), "{err}");
        assert!(err.contains("max feasible"), "{err}");
        // the reported maximum is itself feasible
        let max: usize = err
            .rsplit_once("max feasible override is ")
            .and_then(|(_, tail)| {
                tail.trim_end_matches(')').trim().parse().ok()
            })
            .expect("shortfall message names the feasible maximum");
        assert!(BudgetPlan::derive_with(
            &preset.ladder,
            expert_bytes,
            preset.n_layers,
            preset.n_experts,
            preset.n_shared,
            20 << 20,
            8 << 20,
            Some(max),
        )
        .is_ok());
    }

    #[test]
    fn prop_concurrent_reservations_never_exceed_cap() {
        let mut prop = Prop::new("budget_concurrent");
        prop.run(10, |rng| {
            let cap = 10_000 + rng.below(10_000);
            let b = std::sync::Arc::new(BudgetTracker::new(cap, 0));
            let mut handles = Vec::new();
            for t in 0..4 {
                let b = b.clone();
                let seed = rng.next_u64();
                handles.push(std::thread::spawn(move || {
                    let mut r = crate::util::XorShiftRng::new(seed ^ t);
                    let mut held = Vec::new();
                    for _ in 0..200 {
                        let sz = 1 + r.below(500);
                        if b.try_reserve_hi(sz) {
                            held.push(sz);
                        }
                        if !held.is_empty() && r.below(3) == 0 {
                            b.release_hi(held.swap_remove(0));
                        }
                        assert!(b.hi_used() <= cap + 4 * 500);
                    }
                    held.into_iter().sum::<usize>()
                }));
            }
            let held: usize =
                handles.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(b.hi_used(), held);
            assert!(b.hi_used() <= cap);
        });
    }
}
