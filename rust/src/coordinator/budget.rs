//! Budget model and OOM safety (§3.3).
//!
//! A [`BudgetTracker`] enforces the hard HBM envelope: `M_total` usable
//! bytes, `M_fixed` reserved for non-expert state (KV cache, activations,
//! runtime), and the remainder split between high- and low-precision expert
//! residency. Every promotion must pass `try_reserve` **before** entering
//! the transition pipeline; a successful reservation guarantees the
//! subsequent pool allocation cannot OOM. Reservation/release are atomic
//! (CAS loops) so the migration worker and the policy thread never race the
//! envelope.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::ModelPreset;
use crate::model::expert_bytes;

/// Atomic byte-budget tracker with explicit reserve/release.
#[derive(Debug)]
pub struct BudgetTracker {
    /// Cap for high-precision expert residency (`M_exp_hi_cap`).
    hi_cap: usize,
    /// Cap for low-precision expert residency.
    lo_cap: usize,
    hi_used: AtomicUsize,
    lo_used: AtomicUsize,
    /// Diagnostics.
    pub failed_reservations: AtomicUsize,
}

impl BudgetTracker {
    pub fn new(hi_cap: usize, lo_cap: usize) -> Self {
        Self {
            hi_cap,
            lo_cap,
            hi_used: AtomicUsize::new(0),
            lo_used: AtomicUsize::new(0),
            failed_reservations: AtomicUsize::new(0),
        }
    }

    fn try_reserve_in(used: &AtomicUsize, cap: usize, bytes: usize) -> bool {
        let mut cur = used.load(Ordering::Relaxed);
        loop {
            if cur + bytes > cap {
                return false;
            }
            match used.compare_exchange_weak(
                cur,
                cur + bytes,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Reserve `bytes` of high-precision capacity; false if it would exceed
    /// the cap (the promotion must then be deferred — never forced).
    pub fn try_reserve_hi(&self, bytes: usize) -> bool {
        let ok = Self::try_reserve_in(&self.hi_used, self.hi_cap, bytes);
        if !ok {
            self.failed_reservations.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    /// Release previously reserved high-precision bytes.
    pub fn release_hi(&self, bytes: usize) {
        let prev = self.hi_used.fetch_sub(bytes, Ordering::AcqRel);
        debug_assert!(prev >= bytes, "release_hi underflow");
    }

    pub fn try_reserve_lo(&self, bytes: usize) -> bool {
        let ok = Self::try_reserve_in(&self.lo_used, self.lo_cap, bytes);
        if !ok {
            self.failed_reservations.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    pub fn release_lo(&self, bytes: usize) {
        let prev = self.lo_used.fetch_sub(bytes, Ordering::AcqRel);
        debug_assert!(prev >= bytes, "release_lo underflow");
    }

    pub fn hi_used(&self) -> usize {
        self.hi_used.load(Ordering::Relaxed)
    }

    pub fn lo_used(&self) -> usize {
        self.lo_used.load(Ordering::Relaxed)
    }

    pub fn hi_cap(&self) -> usize {
        self.hi_cap
    }

    pub fn lo_cap(&self) -> usize {
        self.lo_cap
    }

    /// Invariant check (used by tests and debug assertions).
    pub fn within_envelope(&self) -> bool {
        self.hi_used() <= self.hi_cap && self.lo_used() <= self.lo_cap
    }
}

/// Budget initialization (§3.1): derive per-layer high-precision capacity
/// `n_hi` from the envelope.
///
/// Feasibility by construction: with `n_hi` hot experts per layer,
/// `fixed + Σ_layers [n_hi·B_hi + (E − n_hi)·B_lo] ≤ M_total` (shared
/// experts are always hot and accounted separately).
#[derive(Clone, Debug)]
pub struct BudgetPlan {
    /// Per-layer cap on concurrently hi-resident experts.
    pub n_hi_per_layer: usize,
    /// Cap for the high-precision pool in bytes (across layers).
    pub hi_pool_bytes: usize,
    /// Cap for the low-precision pool in bytes.
    pub lo_pool_bytes: usize,
    pub hi_expert_bytes: usize,
    pub lo_expert_bytes: usize,
}

impl BudgetPlan {
    /// Compute the plan for `preset` under `(total, fixed)` bytes.
    ///
    /// Returns an error if even all-cold residency does not fit — the
    /// envelope is then infeasible for this model (the paper's systems
    /// would refuse to start).
    pub fn derive(
        preset: &ModelPreset,
        total_bytes: usize,
        fixed_bytes: usize,
    ) -> Result<Self, String> {
        let b_hi = expert_bytes(preset.hi);
        let b_lo = expert_bytes(preset.lo);
        let layers = preset.n_layers;
        let e = preset.n_experts;
        // Shared experts are pinned at the hi tier, always resident.
        let shared = layers * preset.n_shared * b_hi;
        let baseline = fixed_bytes + shared + layers * e * b_lo;
        if baseline > total_bytes {
            return Err(format!(
                "infeasible envelope: all-cold residency needs {baseline} \
                 bytes but budget is {total_bytes}"
            ));
        }
        let slack = total_bytes - baseline;
        let per_swap = b_hi - b_lo; // promoting one expert frees its lo copy
        let n_hi = (slack / (layers * per_swap)).min(e);
        Ok(Self {
            n_hi_per_layer: n_hi,
            hi_pool_bytes: layers * (n_hi + preset.n_shared) * b_hi,
            lo_pool_bytes: layers * e * b_lo,
            hi_expert_bytes: b_hi,
            lo_expert_bytes: b_lo,
        })
    }

    /// Fraction of experts resident at the hot tier.
    pub fn hot_fraction(&self, preset: &ModelPreset) -> f64 {
        self.n_hi_per_layer as f64 / preset.n_experts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn reserve_release_roundtrip() {
        let b = BudgetTracker::new(100, 50);
        assert!(b.try_reserve_hi(60));
        assert!(!b.try_reserve_hi(41));
        assert!(b.try_reserve_hi(40));
        b.release_hi(60);
        assert_eq!(b.hi_used(), 40);
        assert!(b.within_envelope());
        assert_eq!(
            b.failed_reservations.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn plan_feasible_by_construction() {
        let preset = ModelPreset::qwen30b_sim();
        // scaled-down envelope sized against the *small* executed model
        let total = 20 << 20;
        let fixed = 8 << 20;
        let plan = BudgetPlan::derive(&preset, total, fixed).unwrap();
        let b_hi = plan.hi_expert_bytes;
        let b_lo = plan.lo_expert_bytes;
        let used = fixed
            + preset.n_layers
                * (plan.n_hi_per_layer * b_hi
                    + (preset.n_experts - plan.n_hi_per_layer) * b_lo);
        assert!(used <= total, "plan must fit: {used} > {total}");
        assert!(plan.n_hi_per_layer > 0);
        assert!(plan.n_hi_per_layer < preset.n_experts);
    }

    #[test]
    fn plan_rejects_infeasible() {
        let preset = ModelPreset::qwen30b_sim();
        assert!(BudgetPlan::derive(&preset, 1 << 20, 1 << 19).is_err());
    }

    #[test]
    fn tighter_budget_fewer_hot_experts() {
        let preset = ModelPreset::qwen30b_sim();
        let p1 = BudgetPlan::derive(&preset, 20 << 20, 8 << 20).unwrap();
        let p2 = BudgetPlan::derive(&preset, 17 << 20, 8 << 20).unwrap();
        assert!(p2.n_hi_per_layer < p1.n_hi_per_layer);
    }

    #[test]
    fn shared_experts_accounted() {
        let mut p80 = ModelPreset::qwen80b_sim();
        p80.n_layers = 2;
        let plan = BudgetPlan::derive(&p80, 64 << 20, 4 << 20).unwrap();
        // hi pool must have room for shared experts even at n_hi = 0
        assert!(
            plan.hi_pool_bytes
                >= p80.n_layers * p80.n_shared * plan.hi_expert_bytes
        );
    }

    #[test]
    fn prop_concurrent_reservations_never_exceed_cap() {
        let mut prop = Prop::new("budget_concurrent");
        prop.run(10, |rng| {
            let cap = 10_000 + rng.below(10_000);
            let b = std::sync::Arc::new(BudgetTracker::new(cap, 0));
            let mut handles = Vec::new();
            for t in 0..4 {
                let b = b.clone();
                let seed = rng.next_u64();
                handles.push(std::thread::spawn(move || {
                    let mut r = crate::util::XorShiftRng::new(seed ^ t);
                    let mut held = Vec::new();
                    for _ in 0..200 {
                        let sz = 1 + r.below(500);
                        if b.try_reserve_hi(sz) {
                            held.push(sz);
                        }
                        if !held.is_empty() && r.below(3) == 0 {
                            b.release_hi(held.swap_remove(0));
                        }
                        assert!(b.hi_used() <= cap + 4 * 500);
                    }
                    held.into_iter().sum::<usize>()
                }));
            }
            let held: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(b.hi_used(), held);
            assert!(b.hi_used() <= cap);
        });
    }
}
