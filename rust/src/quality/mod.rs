//! Quality metrics: perplexity and logit fidelity.
//!
//! The paper's six LLM benchmarks are substituted (DESIGN.md §2) by a
//! proxy suite computed on *really executed* numerics: teacher-forced
//! perplexity on synthetic prompts, plus KL divergence and relative error
//! of logits against the FP16 reference. Table 4's claim is the ordering
//! FP16 ≥ DynaExq > static-low-bit with DynaExq recovering most of the
//! gap; these metrics expose exactly that ordering.

use crate::config::VOCAB;

/// Numerically stable log-softmax of one row.
fn log_softmax(row: &[f32]) -> Vec<f64> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let logsum: f64 = row
        .iter()
        .map(|&x| ((x as f64) - max).exp())
        .sum::<f64>()
        .ln()
        + max;
    row.iter().map(|&x| x as f64 - logsum).collect()
}

/// Teacher-forced perplexity of `tokens` under `[T, VOCAB]` logits
/// (position t predicts token t+1).
pub fn perplexity(logits: &[f32], tokens: &[i32]) -> f64 {
    let t = tokens.len();
    assert_eq!(logits.len(), t * VOCAB);
    if t < 2 {
        return f64::NAN;
    }
    let mut nll = 0.0;
    for pos in 0..t - 1 {
        let row = &logits[pos * VOCAB..(pos + 1) * VOCAB];
        let ls = log_softmax(row);
        nll -= ls[tokens[pos + 1] as usize];
    }
    (nll / (t - 1) as f64).exp()
}

/// Mean KL(ref ‖ hyp) across rows of two `[T, VOCAB]` logit matrices.
pub fn logit_kl(reference: &[f32], hypothesis: &[f32]) -> f64 {
    assert_eq!(reference.len(), hypothesis.len());
    let rows = reference.len() / VOCAB;
    let mut total = 0.0;
    for r in 0..rows {
        let p = log_softmax(&reference[r * VOCAB..(r + 1) * VOCAB]);
        let q = log_softmax(&hypothesis[r * VOCAB..(r + 1) * VOCAB]);
        let mut kl = 0.0;
        for v in 0..VOCAB {
            kl += p[v].exp() * (p[v] - q[v]);
        }
        total += kl;
    }
    total / rows as f64
}

/// Relative L2 error between two logit matrices.
pub fn logit_rel_err(reference: &[f32], hypothesis: &[f32]) -> f64 {
    assert_eq!(reference.len(), hypothesis.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..reference.len() {
        let d = (reference[i] - hypothesis[i]) as f64;
        num += d * d;
        den += (reference[i] as f64).powi(2);
    }
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

/// Greedy-token agreement rate between reference and hypothesis logits —
/// the closest analogue of task "accuracy" the proxy suite can measure.
pub fn greedy_agreement(reference: &[f32], hypothesis: &[f32]) -> f64 {
    let rows = reference.len() / VOCAB;
    let mut agree = 0;
    for r in 0..rows {
        let argmax = |xs: &[f32]| {
            let mut b = 0;
            for (i, &x) in xs.iter().enumerate() {
                if x > xs[b] {
                    b = i;
                }
            }
            b
        };
        if argmax(&reference[r * VOCAB..(r + 1) * VOCAB])
            == argmax(&hypothesis[r * VOCAB..(r + 1) * VOCAB])
        {
            agree += 1;
        }
    }
    agree as f64 / rows as f64
}

/// Aggregated quality of one method on one workload.
#[derive(Debug, Clone, Default)]
pub struct QualityReport {
    pub perplexity: f64,
    pub kl_vs_fp16: f64,
    pub rel_err_vs_fp16: f64,
    pub agreement_vs_fp16: f64,
    pub prompts: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    fn rand_logits(rng: &mut XorShiftRng, rows: usize) -> Vec<f32> {
        (0..rows * VOCAB).map(|_| rng.normal_f32() * 2.0).collect()
    }

    #[test]
    fn perplexity_uniform_is_vocab() {
        let t = 16;
        let logits = vec![0f32; t * VOCAB];
        let tokens: Vec<i32> = (0..t as i32).collect();
        let ppl = perplexity(&logits, &tokens);
        assert!((ppl - VOCAB as f64).abs() < 1e-6);
    }

    #[test]
    fn perplexity_confident_is_low() {
        let t = 8;
        let tokens: Vec<i32> = (0..t as i32).collect();
        let mut logits = vec![0f32; t * VOCAB];
        for pos in 0..t - 1 {
            logits[pos * VOCAB + tokens[pos + 1] as usize] = 50.0;
        }
        assert!(perplexity(&logits, &tokens) < 1.001);
    }

    #[test]
    fn kl_zero_for_identical() {
        let mut rng = XorShiftRng::new(1);
        let l = rand_logits(&mut rng, 4);
        assert!(logit_kl(&l, &l).abs() < 1e-9);
        assert_eq!(logit_rel_err(&l, &l), 0.0);
        assert_eq!(greedy_agreement(&l, &l), 1.0);
    }

    #[test]
    fn kl_positive_and_grows_with_noise() {
        let mut rng = XorShiftRng::new(2);
        let l = rand_logits(&mut rng, 8);
        let perturb = |l: &[f32], amp: f32, rng: &mut XorShiftRng| -> Vec<f32> {
            l.iter().map(|&x| x + rng.normal_f32() * amp).collect()
        };
        let small = perturb(&l, 0.05, &mut rng);
        let large = perturb(&l, 1.0, &mut rng);
        let kl_s = logit_kl(&l, &small);
        let kl_l = logit_kl(&l, &large);
        assert!(kl_s > 0.0);
        assert!(kl_l > kl_s);
        assert!(logit_rel_err(&l, &large) > logit_rel_err(&l, &small));
        assert!(greedy_agreement(&l, &small) >= greedy_agreement(&l, &large));
    }
}
