//! Table 4 + Figure 3: quality experiments on **really executed** numerics.
//!
//! Table 4 shape: FP16 ≥ DynaExq > static-low-bit at the same footprint,
//! with DynaExq recovering most of the static loss (and approaching the
//! higher-bit static config on the 80B model). Figure 3 shape: perplexity
//! rises smoothly as more (cold-first) experts per layer are demoted.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::bench::Table;
use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::model::{ModelWeights, Precision};
use crate::quality::{
    greedy_agreement, logit_kl, logit_rel_err, perplexity, QualityReport,
};
use crate::runtime::Runtime;
use crate::serving::backend::{CountingBackend, ResidencyBackend};
use crate::serving::numeric::NumericEngine;
use crate::serving::registry::{BackendCtx, BackendRegistry};
use crate::util::XorShiftRng;
use crate::workload::WorkloadProfile;

use super::helpers::{preset, profile};

/// Per-layer hot capacity derived at paper scale (so the numeric model's
/// hot *fraction* matches what the real model would get under 48 GB).
pub fn logical_n_hi(p: &ModelPreset, cfg: &ServingConfig) -> Result<usize> {
    let plan = crate::coordinator::Coordinator::plan_for(p, cfg)
        .map_err(|e| anyhow!(e))?;
    Ok(plan.n_hi_per_layer())
}

/// Methods meaningful in the numeric quality harness. Offloading methods
/// (`expertflow`, `hobbit`) plan their envelope at paper scale, which the
/// executed small model cannot represent — a default-budget plan would
/// degenerate to all-hi residency and misreport the baseline.
pub const QUALITY_METHODS: &[&str] =
    &["fp16", "static", "static-hi", "dynaexq", "static-map"];

fn make_backend(
    method: &str,
    exec_preset: &ModelPreset,
    plan_preset: &ModelPreset,
    n_hi: Option<usize>,
    calib_counts: Option<&[Vec<u64>]>,
) -> Result<Box<dyn ResidencyBackend>> {
    if !QUALITY_METHODS.contains(&method) {
        return Err(anyhow!(
            "method {method:?} is not a quality method; quality methods: {}",
            QUALITY_METHODS.join(", ")
        ));
    }
    let mut cfg = ServingConfig::default();
    if matches!(method, "dynaexq" | "static-map") {
        // Hot capacity per layer comes from the *paper-scale* plan
        // (48 GB envelope over the real model's layer count) so the
        // executed model's hot fraction matches deployment.
        cfg.n_hi_override = Some(match n_hi {
            Some(n) => n,
            None => logical_n_hi(plan_preset, &ServingConfig::default())?,
        });
    }
    if method == "dynaexq" {
        cfg.max_inflight_promotions = 64;
    }
    let dev = DeviceConfig::default();
    let mut ctx = BackendCtx::new(exec_preset, &cfg, &dev);
    if let Some(c) = calib_counts {
        ctx = ctx.with_counts(c);
    }
    BackendRegistry::with_builtins()
        .build(method, &ctx)
        .map_err(|e| anyhow!(e))
}

/// Shared fixture: runtime + weights for one model (expensive — reuse).
pub struct QualityFixture {
    pub rt: Arc<Runtime>,
    pub weights: Arc<ModelWeights>,
    pub exec_preset: ModelPreset,
    /// Original preset (paper layer count) used for budget planning.
    pub plan_preset: ModelPreset,
}

impl QualityFixture {
    pub fn new(model: &str) -> Result<Self> {
        let plan_preset = preset(model)?;
        let p = plan_preset.executed_scale();
        let weights = Arc::new(ModelWeights::generate(&p, 0xDA7A ^ p.n_experts as u64));
        let rt = Arc::new(Runtime::load_default()?);
        Ok(Self { rt, weights, exec_preset: p, plan_preset })
    }

    /// Evaluate one method on `n_prompts` prompts; returns (per-prompt
    /// logits, ppl mean). DynaExq gets a warmup phase on the same workload
    /// so its hotness estimate converges before measurement; `static-map`
    /// gets a real (numeric-router) calibration pass on the same workload
    /// before its map is fixed — the modeled-sampler fallback the registry
    /// uses elsewhere does not describe the numeric engine's routing.
    pub fn eval(
        &self,
        method: &str,
        workload: &WorkloadProfile,
        n_prompts: usize,
        prompt_len: usize,
        n_hi: Option<usize>,
    ) -> Result<(Vec<Vec<f32>>, f64)> {
        if method == "static-map" {
            let counts =
                self.calibrate_counts(workload, n_prompts, prompt_len)?;
            let backend = make_backend(
                method,
                &self.exec_preset,
                &self.plan_preset,
                n_hi,
                Some(&counts),
            )?;
            return self
                .eval_backend(backend, false, workload, n_prompts, prompt_len);
        }
        let backend = make_backend(
            method,
            &self.exec_preset,
            &self.plan_preset,
            n_hi,
            None,
        )?;
        self.eval_backend(
            backend,
            method == "dynaexq",
            workload,
            n_prompts,
            prompt_len,
        )
    }

    /// Evaluate an arbitrary residency backend (baselines A5/A6 build their
    /// own). When `warm_adaptive`, a warmup phase on the same workload runs
    /// first and the residency map is then quiesced + pinned.
    pub fn eval_backend(
        &self,
        backend: Box<dyn ResidencyBackend>,
        warm_adaptive: bool,
        workload: &WorkloadProfile,
        n_prompts: usize,
        prompt_len: usize,
    ) -> Result<(Vec<Vec<f32>>, f64)> {
        let mut eng = NumericEngine::new(
            self.rt.clone(),
            self.weights.clone(),
            backend,
        )?;
        let mut rng = XorShiftRng::new(workload.seed ^ 0xE7A1);
        if warm_adaptive {
            // Warmup: route traffic so the scheduler promotes the hot set.
            for i in 0..3 {
                let prompt = workload.sample_prompt(&mut rng, prompt_len);
                let _ = eng.prefill(&prompt, 1000 + i)?;
            }
            // Materialize all pending transitions, then freeze the
            // precision map for the eval window (paper: window pinning).
            eng.quiesce();
        }
        // Fixed eval seed: every method sees identical prompts.
        let mut eval_rng = XorShiftRng::new(workload.seed ^ 0x9d2c);
        let mut logits_all = Vec::with_capacity(n_prompts);
        let mut ppl_sum = 0.0;
        for i in 0..n_prompts {
            let prompt = workload.sample_prompt(&mut eval_rng, prompt_len);
            let (_kv, logits) = eng.prefill(&prompt, i as u64)?;
            ppl_sum += perplexity(&logits, &prompt);
            logits_all.push(logits);
        }
        Ok((logits_all, ppl_sum / n_prompts as f64))
    }

    /// Offline calibration pass: record per-(layer, expert) routing counts
    /// on `workload` with the real router (the A5 static-map input).
    pub fn calibrate_counts(
        &self,
        workload: &WorkloadProfile,
        n_prompts: usize,
        prompt_len: usize,
    ) -> Result<Vec<Vec<u64>>> {
        let backend = CountingBackend::new(
            self.exec_preset.n_layers,
            self.exec_preset.n_experts,
            Precision::Fp16,
        );
        let mut eng = NumericEngine::new(
            self.rt.clone(),
            self.weights.clone(),
            Box::new(backend),
        )?;
        let mut rng = XorShiftRng::new(workload.seed ^ 0xCA1B);
        for i in 0..n_prompts {
            let prompt = workload.sample_prompt(&mut rng, prompt_len);
            let _ = eng.prefill(&prompt, i as u64)?;
        }
        Ok(eng.backend_counts().expect("counting backend").to_vec())
    }
}

/// One (model, method, workload) quality report vs the FP16 reference.
pub fn run_quality(
    model: &str,
    method: &str,
    workload: &str,
    n_prompts: usize,
    prompt_len: usize,
) -> Result<QualityReport> {
    let fixture = QualityFixture::new(model)?;
    let w = profile(workload)?;
    let (ref_logits, _) =
        fixture.eval("fp16", &w, n_prompts, prompt_len, None)?;
    let (hyp_logits, ppl) =
        fixture.eval(method, &w, n_prompts, prompt_len, None)?;
    let mut kl = 0.0;
    let mut rel = 0.0;
    let mut agree = 0.0;
    for (r, h) in ref_logits.iter().zip(&hyp_logits) {
        kl += logit_kl(r, h);
        rel += logit_rel_err(r, h);
        agree += greedy_agreement(r, h);
    }
    let n = n_prompts as f64;
    Ok(QualityReport {
        perplexity: ppl,
        kl_vs_fp16: kl / n,
        rel_err_vs_fp16: rel / n,
        agreement_vs_fp16: agree / n,
        prompts: n_prompts,
    })
}

/// Table 4: quality proxy across models × methods × workloads.
pub fn table4_quality(fast: bool) -> Result<String> {
    let (n_prompts, prompt_len) = if fast { (2, 32) } else { (6, 64) };
    let models: &[&str] = if fast {
        &["phi-sim"]
    } else {
        &["qwen30b-sim", "qwen80b-sim", "phi-sim"]
    };
    let mut t = Table::new(&[
        "model", "method", "ppl", "KL vs fp16", "relerr", "greedy-agree",
    ]);
    for model in models {
        let fixture = QualityFixture::new(model)?;
        let w = WorkloadProfile::text();
        let (ref_logits, ref_ppl) =
            fixture.eval("fp16", &w, n_prompts, prompt_len, None)?;
        t.row(&[
            model.to_string(),
            "fp16".into(),
            format!("{ref_ppl:.3}"),
            "0.0".into(),
            "0.0".into(),
            "1.000".into(),
        ]);
        for method in ["static", "dynaexq"] {
            let (hyp, ppl) =
                fixture.eval(method, &w, n_prompts, prompt_len, None)?;
            let n = n_prompts as f64;
            let kl: f64 = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| logit_kl(r, h))
                .sum::<f64>()
                / n;
            let rel: f64 = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| logit_rel_err(r, h))
                .sum::<f64>()
                / n;
            let agree: f64 = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| greedy_agreement(r, h))
                .sum::<f64>()
                / n;
            t.row(&[
                model.to_string(),
                method.into(),
                format!("{ppl:.3}"),
                format!("{kl:.5}"),
                format!("{rel:.4}"),
                format!("{agree:.3}"),
            ]);
        }
    }
    Ok(format!(
        "== Table 4 (proxy): quality across models/methods — static = \
         uniform lo tier, dynaexq = hot experts at hi tier ==\n{}",
        t.render()
    ))
}

/// Figure 3: quality degradation vs number of demoted (cold) experts per
/// layer. Primary metric is logit divergence from the hi-tier reference
/// (perplexity on synthetic untrained weights is noise-dominated; KL
/// exposes the same smooth, monotone curve the paper's Fig. 3 shows).
pub fn figure3_demotion(fast: bool) -> Result<String> {
    let (n_prompts, prompt_len) = if fast { (2, 32) } else { (4, 64) };
    let models: &[&str] = if fast {
        &["phi-sim"]
    } else {
        &["qwen30b-sim", "phi-sim"]
    };
    let mut out = String::from(
        "== Figure 3 (proxy): logit KL vs hi-tier reference as cold \
         experts are demoted per layer ==\n",
    );
    for model in models {
        let fixture = QualityFixture::new(model)?;
        let e = fixture.exec_preset.n_experts;
        let w = WorkloadProfile::text();
        // hi-tier reference: everything at the model's hi precision
        let (ref_logits, _) =
            fixture.eval("static-hi", &w, n_prompts, prompt_len, None)?;
        let fracs = [0.0, 0.25, 0.5, 0.75, 0.875, 1.0];
        let mut t =
            Table::new(&["demoted/layer", "n_hi", "KL vs hi", "relerr", "ppl"]);
        for f in fracs {
            let demoted = ((e as f64) * f).round() as usize;
            let n_hi = e - demoted;
            let (hyp, ppl) =
                fixture.eval("dynaexq", &w, n_prompts, prompt_len, Some(n_hi))?;
            let n = n_prompts as f64;
            let kl: f64 = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| logit_kl(r, h))
                .sum::<f64>()
                / n;
            let rel: f64 = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| logit_rel_err(r, h))
                .sum::<f64>()
                / n;
            t.row(&[
                format!("{demoted}"),
                format!("{n_hi}"),
                format!("{kl:.5}"),
                format!("{rel:.4}"),
                format!("{ppl:.3}"),
            ]);
        }
        out.push_str(&format!(
            "-- {model} ({} experts/layer, hot={} cold={}) --\n{}",
            e,
            fixture.exec_preset.hi().tag(),
            fixture.exec_preset.lo().tag(),
            t.render()
        ));
    }
    Ok(out)
}
