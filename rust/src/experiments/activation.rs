//! Tables 1–2: expert activation ratio (%) vs batch size, decode & prefill.
//!
//! Paper shape: ratios rise steeply with batch; prefill ≫ decode; at
//! batch 1 decode the ratio is ≈ top_k / n_experts.

use std::collections::HashSet;

use anyhow::Result;

use crate::bench::Table;
use crate::util::XorShiftRng;
use crate::workload::{RoutingSampler, WorkloadProfile};

use super::helpers::{engine, preset, BATCHES};

const MODELS: &[&str] = &["qwen30b-sim", "qwen80b-sim", "phi-sim"];

/// Prefill activation = fraction of a layer's experts touched while a
/// *batch* of prompts prefills together in one iteration (the paper's
/// Table 2 regime), measured as the union across the batch.
fn prefill_union_ratio(
    model: &str,
    batch: usize,
    prompt_len: usize,
    rounds: usize,
) -> Result<f64> {
    let p = preset(model)?;
    let w = WorkloadProfile::text();
    let s = RoutingSampler::new(&w, p.n_layers_logical(), p.n_experts, p.top_k);
    let mut rng = XorShiftRng::new(0x7e57 ^ batch as u64);
    let mut acc = 0.0;
    let mut samples = 0;
    let mut tag_base = 0u64;
    for _ in 0..rounds {
        for layer in 0..4 {
            let mut union: HashSet<usize> = HashSet::new();
            for req in 0..batch as u64 {
                for _ in 0..prompt_len {
                    union.extend(s.sample_topk(&mut rng, tag_base + req, layer));
                }
            }
            acc += union.len() as f64 / p.n_experts as f64;
            samples += 1;
        }
        tag_base += batch as u64;
    }
    Ok(acc / samples as f64)
}

fn activation_row(
    model: &str,
    batches: &[usize],
    prefill: bool,
    fast: bool,
) -> Result<Vec<String>> {
    let mut cells = vec![model.to_string()];
    for &b in batches {
        let ratio = if prefill {
            let prompt = if fast { 256 } else { 512 };
            prefill_union_ratio(model, b, prompt, if fast { 1 } else { 2 })?
        } else {
            let mut e = engine(model, "static", "text", 7 + b as u64, true)?;
            let rounds = if fast { 2 } else { 4 };
            let w = WorkloadProfile::text();
            for _ in 0..rounds {
                e.serve_uniform(&w, b, 16, 16);
            }
            e.activation.decode_avg()
        };
        cells.push(format!("{:.1}", ratio * 100.0));
    }
    Ok(cells)
}

/// Table 1: decode-stage activation ratio (%).
pub fn table1_decode(fast: bool) -> Result<String> {
    let batches = if fast { &BATCHES[..4] } else { BATCHES };
    let mut headers = vec!["Model"];
    let labels: Vec<String> =
        batches.iter().map(|b| format!("bs={b}")).collect();
    headers.extend(labels.iter().map(String::as_str));
    let mut t = Table::new(&headers);
    for m in MODELS {
        t.row(&activation_row(m, batches, false, fast)?);
    }
    Ok(format!(
        "== Table 1: expert activation ratio (%) in decode stage ==\n{}",
        t.render()
    ))
}

/// Table 2: prefill-stage activation ratio (%).
pub fn table2_prefill(fast: bool) -> Result<String> {
    let batches = if fast { &BATCHES[..4] } else { BATCHES };
    let mut headers = vec!["Model"];
    let labels: Vec<String> =
        batches.iter().map(|b| format!("bs={b}")).collect();
    headers.extend(labels.iter().map(String::as_str));
    let mut t = Table::new(&headers);
    for m in MODELS {
        t.row(&activation_row(m, batches, true, fast)?);
    }
    Ok(format!(
        "== Table 2: expert activation ratio (%) in prefill stage ==\n{}",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_ratio_grows_with_batch() {
        let row = activation_row("qwen30b-sim", &[1, 16], false, true).unwrap();
        let r1: f64 = row[1].parse().unwrap();
        let r16: f64 = row[2].parse().unwrap();
        // batch 1 ≈ top_k/E = 6.3%; batch 16 far denser
        assert!(r1 < 12.0, "batch-1 decode ratio {r1}");
        assert!(r16 > 2.0 * r1, "batch-16 {r16} vs batch-1 {r1}");
    }

    #[test]
    fn prefill_much_denser_than_decode() {
        let pre = activation_row("phi-sim", &[2], true, true).unwrap();
        let dec = activation_row("phi-sim", &[2], false, true).unwrap();
        let p: f64 = pre[1].parse().unwrap();
        let d: f64 = dec[1].parse().unwrap();
        assert!(p > 1.5 * d, "prefill {p}% vs decode {d}%");
    }

    #[test]
    fn prefill_union_grows_with_batch() {
        // Table 2 shape: batched prefill densifies with batch size.
        let r1 = prefill_union_ratio("qwen30b-sim", 1, 256, 1).unwrap();
        let r8 = prefill_union_ratio("qwen30b-sim", 8, 256, 1).unwrap();
        assert!(r8 > r1, "bs8 {r8} vs bs1 {r1}");
        assert!(r1 > 0.3 && r1 < 0.7, "bs1 prefill ratio {r1}");
    }
}
