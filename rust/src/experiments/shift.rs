//! Figure 2: expert activation counts under different workloads — a small
//! hot set dominates cumulative activations, and the top-10 hot sets of
//! text/math/code are (near-)disjoint.

use std::collections::HashSet;

use anyhow::Result;

use crate::bench::Table;
use crate::util::XorShiftRng;
use crate::workload::{RoutingSampler, WorkloadProfile};

use super::helpers::preset;

/// Cumulative per-expert counts for one workload at `layer`.
pub fn cumulative_counts(
    model: &str,
    workload: &WorkloadProfile,
    layer: usize,
    iters: usize,
) -> Result<Vec<u64>> {
    let p = preset(model)?;
    let s = RoutingSampler::new(
        workload,
        p.n_layers_logical(),
        p.n_experts,
        p.top_k,
    );
    let mut rng = XorShiftRng::new(workload.seed ^ 0xACE);
    let mut counts = vec![0u64; p.n_experts];
    for tag in 0..iters as u64 {
        for _ in 0..8 {
            for e in s.sample_topk(&mut rng, tag, layer) {
                counts[e] += 1;
            }
        }
    }
    Ok(counts)
}

fn top_n(counts: &[u64], n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..counts.len()).collect();
    idx.sort_by_key(|&e| std::cmp::Reverse(counts[e]));
    idx.truncate(n);
    idx
}

/// Figure 2 harness: per-workload hot heads + pairwise overlap + skew.
pub fn figure2_shift(fast: bool) -> Result<String> {
    let iters = if fast { 200 } else { 1000 };
    let layer = 15 % 48; // the paper plots layer 15 of Qwen3-MoE-30B
    let mut out = String::from(
        "== Figure 2: expert activation counts across workloads \
         (qwen30b-sim, layer 15) ==\n",
    );
    let mut tops: Vec<(String, Vec<usize>, Vec<u64>)> = Vec::new();
    for w in WorkloadProfile::all() {
        let counts = cumulative_counts("qwen30b-sim", &w, layer, iters)?;
        let top = top_n(&counts, 10);
        let total: u64 = counts.iter().sum();
        let top_share: u64 = top.iter().map(|&e| counts[e]).sum();
        out.push_str(&format!(
            "{:<6} top-10 experts {:?}  (top-10 share {:.1}% of traffic)\n",
            w.name,
            top,
            top_share as f64 / total as f64 * 100.0
        ));
        tops.push((w.name.to_string(), top, counts));
    }
    let mut t = Table::new(&["pair", "top-10 overlap"]);
    for i in 0..tops.len() {
        for j in i + 1..tops.len() {
            let a: HashSet<_> = tops[i].1.iter().collect();
            let b: HashSet<_> = tops[j].1.iter().collect();
            t.row(&[
                format!("{}/{}", tops[i].0, tops[j].0),
                format!("{}", a.intersection(&b).count()),
            ]);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

/// `dynaexq trace` backing: routing statistics of one workload.
pub fn trace_stats(model: &str, workload: &str, iters: usize) -> Result<String> {
    let w = WorkloadProfile::by_name(workload)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {workload:?}"))?;
    let counts = cumulative_counts(model, &w, 0, iters)?;
    let total: u64 = counts.iter().sum();
    let mut sorted = counts.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let cum_at = |frac: f64| -> f64 {
        let n = ((counts.len() as f64) * frac).ceil() as usize;
        sorted[..n].iter().sum::<u64>() as f64 / total as f64 * 100.0
    };
    Ok(format!(
        "workload {workload} on {model}: {} selections over {} experts\n\
         traffic share: top-5% experts {:.1}%  top-10% {:.1}%  top-25% {:.1}%\n\
         hottest 10: {:?}",
        total,
        counts.len(),
        cum_at(0.05),
        cum_at(0.10),
        cum_at(0.25),
        top_n(&counts, 10),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_sets_disjoint_across_workloads() {
        let mut tops = Vec::new();
        for w in WorkloadProfile::all() {
            let c = cumulative_counts("qwen30b-sim", &w, 15, 150).unwrap();
            tops.push(top_n(&c, 10).into_iter().collect::<HashSet<_>>());
        }
        let overlap = tops[0].intersection(&tops[1]).count()
            + tops[0].intersection(&tops[2]).count()
            + tops[1].intersection(&tops[2]).count();
        assert!(overlap <= 3, "total pairwise overlap {overlap}");
    }

    #[test]
    fn traffic_heavy_tailed() {
        let w = WorkloadProfile::text();
        let c = cumulative_counts("qwen30b-sim", &w, 15, 150).unwrap();
        let total: u64 = c.iter().sum();
        let top = top_n(&c, 13); // ~10% of 128
        let share: u64 = top.iter().map(|&e| c[e]).sum();
        assert!(
            share as f64 > 0.3 * total as f64,
            "top-10% carries {share}/{total}"
        );
    }
}
