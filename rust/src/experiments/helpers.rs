//! Shared construction helpers for the experiment harnesses.
//!
//! All backend construction routes through the
//! [`BackendRegistry`](crate::serving::registry::BackendRegistry); these
//! helpers only add the experiment-harness conveniences (name → preset /
//! profile lookups, warmup, one-call sessions).

use anyhow::{anyhow, Result};

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::serving::backend::ResidencyBackend;
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::registry::{BackendCtx, BackendRegistry};
use crate::serving::session::ServeSession;
use crate::workload::{Scenario, WorkloadProfile};

/// Methods compared across the paper's performance experiments (every
/// batch-sweep figure runs each of these; the registry knows more — e.g.
/// the quality-only `fp16`/`static-hi` tiers and the `counting` pass).
pub const METHODS: &[&str] =
    &["static", "dynaexq", "expertflow", "hobbit", "static-map"];

/// The paper's batch-size sweep.
pub const BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32];

pub fn preset(model: &str) -> Result<ModelPreset> {
    ModelPreset::by_name(model)
        .ok_or_else(|| anyhow!("unknown model {model:?}"))
}

pub fn profile(workload: &str) -> Result<WorkloadProfile> {
    WorkloadProfile::by_name(workload)
        .ok_or_else(|| anyhow!("unknown workload {workload:?}"))
}

pub fn scenario(name: &str) -> Result<Scenario> {
    Scenario::by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown scenario {name:?}; known scenarios: {}",
            Scenario::names().join(", ")
        )
    })
}

/// Build a residency backend for a method name (registry lookup). Pass the
/// serving workload when one is known — offline-calibrated methods
/// (static-map) use it as their calibration input.
pub fn backend(
    method: &str,
    preset: &ModelPreset,
    cfg: &ServingConfig,
    dev: &DeviceConfig,
    workload: Option<&WorkloadProfile>,
) -> Result<Box<dyn ResidencyBackend>> {
    backend_with_devices(method, preset, cfg, dev, workload, 1)
}

/// [`backend`] with an explicit device-group width (sharded methods
/// consume it; single-device methods ignore it).
pub fn backend_with_devices(
    method: &str,
    preset: &ModelPreset,
    cfg: &ServingConfig,
    dev: &DeviceConfig,
    workload: Option<&WorkloadProfile>,
    devices: usize,
) -> Result<Box<dyn ResidencyBackend>> {
    let mut ctx = BackendCtx::new(preset, cfg, dev).with_devices(devices);
    if let Some(w) = workload {
        ctx = ctx.with_profile(w);
    }
    BackendRegistry::with_builtins()
        .build(method, &ctx)
        .map_err(|e| anyhow!(e))
}

/// Build a modeled engine for (model, method, workload).
pub fn engine(
    model: &str,
    method: &str,
    workload: &str,
    seed: u64,
    track_activation: bool,
) -> Result<Engine> {
    let p = preset(model)?;
    let w = profile(workload)?;
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    // The serving workload is the calibration input for offline-calibrated
    // methods (static-map).
    let b = backend(method, &p, &cfg, &dev, Some(&w))?;
    Ok(Engine::new(
        &p,
        &w,
        b,
        &dev,
        EngineConfig { max_batch: 32, seed, track_activation },
    ))
}

/// Warm an adaptive method to steady state before measuring (thin alias
/// for [`Engine::warm`], kept for the experiment harnesses).
pub fn warm(engine: &mut Engine, workload: &WorkloadProfile, rounds: usize) {
    engine.warm(workload, rounds);
}

/// One self-contained serving session (CLI `serve`), on the builder API.
/// Returns the session (for snapshots) plus its human-readable report.
#[allow(clippy::too_many_arguments)]
pub fn serve_session_with(
    model: &str,
    method: &str,
    workload: &str,
    batch: usize,
    prompt: usize,
    output: usize,
    rounds: usize,
    seed: u64,
    warmup: usize,
    devices: usize,
) -> Result<(ServeSession, String)> {
    let mut s = ServeSession::builder()
        .model(model)
        .method(method)
        .workload(workload)
        .seed(seed)
        .warmup(warmup)
        .devices(devices)
        .build()?;
    s.serve_rounds(rounds, batch, prompt, output)?;
    let devices_note = if devices > 1 {
        format!(" | devices {devices}")
    } else {
        String::new()
    };
    let report = format!(
        "model {model} | method {method} | workload {workload} | \
         batch {batch} prompt {prompt} output {output} × {rounds} \
         rounds{devices_note}\n{}",
        s.report(),
    );
    Ok((s, report))
}

/// [`serve_session_with`] at the default seed + warmup, single device,
/// report only.
pub fn serve_session(
    model: &str,
    method: &str,
    workload: &str,
    batch: usize,
    prompt: usize,
    output: usize,
    rounds: usize,
) -> Result<String> {
    let (_, report) = serve_session_with(
        model, method, workload, batch, prompt, output, rounds, 0xC0FFEE, 2,
        1,
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_factory_covers_methods() {
        let p = preset("phi-sim").unwrap();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        for m in METHODS {
            let b = backend(m, &p, &cfg, &dev, None).unwrap();
            assert!(!b.name().is_empty());
        }
        let err =
            backend("nope", &p, &cfg, &dev, None).unwrap_err().to_string();
        assert!(err.contains("hobbit") && err.contains("static-map"), "{err}");
    }

    #[test]
    fn engine_covers_all_serving_methods() {
        for m in METHODS {
            let mut e = engine("phi-sim", m, "text", 1, false).unwrap();
            e.serve_uniform(&WorkloadProfile::text(), 2, 16, 2);
            assert_eq!(e.metrics.e2e.count(), 2, "{m}");
        }
    }

    #[test]
    fn scenario_lookup_enumerates_known() {
        assert_eq!(scenario("swap").unwrap().phases.len(), 2);
        let err = scenario("nope").unwrap_err().to_string();
        assert!(err.contains("steady") && err.contains("diurnal"), "{err}");
    }

    #[test]
    fn serve_session_produces_report() {
        let s =
            serve_session("phi-sim", "static", "text", 2, 32, 4, 1).unwrap();
        assert!(s.contains("tok/s"));
    }
}
