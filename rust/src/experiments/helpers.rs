//! Shared construction helpers for the experiment harnesses.

use anyhow::{anyhow, Result};

use crate::baselines::ExpertFlowBackend;
use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::serving::backend::{DynaExqBackend, ResidencyBackend, StaticBackend};
use crate::serving::engine::{Engine, EngineConfig};
use crate::workload::WorkloadProfile;

/// Methods compared across the paper's performance experiments.
pub const METHODS: &[&str] = &["static", "dynaexq", "expertflow"];

/// The paper's batch-size sweep.
pub const BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32];

pub fn preset(model: &str) -> Result<ModelPreset> {
    ModelPreset::by_name(model)
        .ok_or_else(|| anyhow!("unknown model {model:?}"))
}

pub fn profile(workload: &str) -> Result<WorkloadProfile> {
    WorkloadProfile::by_name(workload)
        .ok_or_else(|| anyhow!("unknown workload {workload:?}"))
}

/// Build a residency backend for a method name.
pub fn backend(
    method: &str,
    preset: &ModelPreset,
    cfg: &ServingConfig,
    dev: &DeviceConfig,
) -> Result<Box<dyn ResidencyBackend>> {
    Ok(match method {
        "static" => Box::new(StaticBackend::for_preset(preset)),
        "dynaexq" => Box::new(
            DynaExqBackend::new(preset, cfg, dev).map_err(|e| anyhow!(e))?,
        ),
        "expertflow" => Box::new(ExpertFlowBackend::new(preset, cfg, dev)),
        other => return Err(anyhow!("unknown method {other:?}")),
    })
}

/// Build a modeled engine for (model, method, workload).
pub fn engine(
    model: &str,
    method: &str,
    workload: &str,
    seed: u64,
    track_activation: bool,
) -> Result<Engine> {
    let p = preset(model)?;
    let w = profile(workload)?;
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let b = backend(method, &p, &cfg, &dev)?;
    Ok(Engine::new(
        &p,
        &w,
        b,
        &dev,
        EngineConfig { max_batch: 32, seed, track_activation },
    ))
}

/// Warm an adaptive method to steady state before measuring (the paper
/// measures converged serving, not cold start).
pub fn warm(engine: &mut Engine, workload: &WorkloadProfile, rounds: usize) {
    for _ in 0..rounds {
        engine.serve_uniform(workload, 8, 128, 16);
    }
    // discard warmup metrics
    engine.metrics = Default::default();
    engine.activation = Default::default();
}

/// One self-contained serving session (CLI `serve`).
pub fn serve_session(
    model: &str,
    method: &str,
    workload: &str,
    batch: usize,
    prompt: usize,
    output: usize,
    rounds: usize,
) -> Result<String> {
    let w = profile(workload)?;
    let mut e = engine(model, method, workload, 0xC0FFEE, true)?;
    warm(&mut e, &w, 2);
    for _ in 0..rounds {
        e.serve_uniform(&w, batch, prompt, output);
    }
    Ok(format!(
        "model {model} | method {method} | workload {workload} | \
         batch {batch} prompt {prompt} output {output} × {rounds} rounds\n\
         {}\nactivation: prefill {:.1}% decode {:.1}% | hi-tier {:.1}% | \
         migrated {:.1} GB | wait p99 {:.4}s",
        e.metrics.summary(),
        e.activation.prefill_avg() * 100.0,
        e.activation.decode_avg() * 100.0,
        e.backend.hi_fraction() * 100.0,
        e.backend.migrated_bytes() as f64 / 1e9,
        e.metrics.wait.p99(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_factory_covers_methods() {
        let p = preset("phi-sim").unwrap();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        for m in METHODS {
            let b = backend(m, &p, &cfg, &dev).unwrap();
            assert!(!b.name().is_empty());
        }
        assert!(backend("nope", &p, &cfg, &dev).is_err());
    }

    #[test]
    fn serve_session_produces_report() {
        let s =
            serve_session("phi-sim", "static", "text", 2, 32, 4, 1).unwrap();
        assert!(s.contains("tok/s"));
    }
}
