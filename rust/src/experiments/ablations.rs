//! Ablations on the design choices DESIGN.md §6 calls out.

use anyhow::{anyhow, Result};

use crate::bench::{Bench, Table};
use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::coordinator::BlockPool;
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::registry::{BackendCtx, BackendRegistry};
use crate::serving::session::ServeSession;
use crate::workload::WorkloadProfile;

fn dynaexq_engine(
    preset: &ModelPreset,
    cfg: ServingConfig,
    seed: u64,
) -> Result<Engine> {
    let dev = DeviceConfig::default();
    let b = BackendRegistry::with_builtins()
        .build("dynaexq", &BackendCtx::new(preset, &cfg, &dev))
        .map_err(|e| anyhow!(e))?;
    Ok(Engine::new(
        preset,
        &WorkloadProfile::text(),
        b,
        &dev,
        EngineConfig { max_batch: 32, seed, track_activation: false },
    ))
}

/// Steady-state migration volume (churn proxy).
///
/// Hysteresis targets churn from *transient routing fluctuations* around
/// the residency boundary (§3.5) — not the unavoidable migration of a real
/// workload shift. The harness therefore converges the hot set first, then
/// measures migration over additional rounds of the same workload: any
/// bytes moved there are pure boundary churn.
fn run_churn(margin: f64, rounds: usize, seed: u64) -> Result<(u64, f64)> {
    let preset = ModelPreset::qwen30b_sim();
    let mut cfg = ServingConfig::default();
    cfg.hysteresis_margin = margin;
    let mut e = dynaexq_engine(&preset, cfg, seed)?;
    let w = WorkloadProfile::text();
    // converge
    for _ in 0..rounds * 2 {
        e.serve_uniform(&w, 8, 64, 16);
    }
    let before = e.backend.migrated_bytes();
    // steady state: same workload, fresh request tags keep scores noisy
    for _ in 0..rounds {
        e.serve_uniform(&w, 8, 64, 16);
    }
    let migrated = e.backend.migrated_bytes() - before;
    let hi = e.backend.hi_fraction();
    Ok((migrated, hi))
}

/// A1: hysteresis margin vs transition churn.
pub fn a1_hysteresis(fast: bool) -> Result<String> {
    let rounds = if fast { 3 } else { 8 };
    let mut t = Table::new(&["margin", "steady-state migrated GB", "hi-tier %"]);
    let mut prev = u64::MAX;
    let mut monotone = true;
    for margin in [0.0, 0.05, 0.1, 0.3, 0.6] {
        let (migrated, hi) = run_churn(margin, rounds, 0xAB1)?;
        if migrated > prev {
            monotone = false;
        }
        prev = migrated;
        t.row(&[
            format!("{margin}"),
            format!("{:.2}", migrated as f64 / 1e9),
            format!("{:.1}", hi * 100.0),
        ]);
    }
    Ok(format!(
        "== A1: hysteresis margin vs steady-state migration churn \
         (qwen30b-sim, stationary workload) ==\n{}\
         churn monotone non-increasing: {monotone}\n",
        t.render()
    ))
}

/// A2: EMA α + update interval vs adaptation after a workload shift.
pub fn a2_ema_alpha(fast: bool) -> Result<String> {
    let rounds = if fast { 2 } else { 5 };
    let preset = ModelPreset::qwen30b_sim();
    let mut t =
        Table::new(&["alpha", "T_u (ms)", "hi-tier % after shift"]);
    for (alpha, tu) in
        [(0.0, 50.0), (0.5, 50.0), (0.8, 50.0), (0.95, 50.0), (0.8, 200.0)]
    {
        let mut cfg = ServingConfig::default();
        cfg.ema_alpha = alpha;
        cfg.update_interval_ms = tu;
        let mut e = dynaexq_engine(&preset, cfg, 0xA2)?;
        // converge on text...
        let text = WorkloadProfile::text();
        for _ in 0..rounds * 2 {
            e.serve_uniform(&text, 8, 64, 16);
        }
        // ...shift to code, measure how much of the new traffic is hot
        let code = WorkloadProfile::code();
        e.set_profile(&code);
        e.metrics = Default::default();
        // reset hi-tier accounting by serving and reading fraction fresh
        for _ in 0..rounds {
            e.serve_uniform(&code, 8, 64, 16);
        }
        t.row(&[
            format!("{alpha}"),
            format!("{tu}"),
            format!("{:.1}", e.backend.hi_fraction() * 100.0),
        ]);
    }
    Ok(format!(
        "== A2: responsiveness (hi-tier share shortly after a text→code \
         shift; higher = faster adaptation) ==\n{}",
        t.render()
    ))
}

/// A3: blocking vs non-blocking transitions.
pub fn a3_blocking(fast: bool) -> Result<String> {
    let rounds = if fast { 2 } else { 5 };
    let preset = ModelPreset::qwen30b_sim();
    let mut t = Table::new(&[
        "transitions", "ttft avg", "ttft p99", "tpop avg", "tput tok/s",
    ]);
    for blocking in [false, true] {
        let mut cfg = ServingConfig::default();
        cfg.blocking_transitions = blocking;
        let mut e = dynaexq_engine(&preset, cfg, 0xA3)?;
        let w = WorkloadProfile::text();
        for _ in 0..rounds {
            e.serve_uniform(&w, 8, 256, 32);
        }
        t.row(&[
            if blocking { "blocking" } else { "non-blocking (VER)" }.into(),
            format!("{:.3}", e.metrics.ttft.avg()),
            format!("{:.3}", e.metrics.ttft.p99()),
            format!("{:.4}", e.metrics.tpop.avg()),
            format!("{:.0}", e.metrics.throughput()),
        ]);
    }
    Ok(format!(
        "== A3: blocking vs non-blocking precision transitions ==\n{}",
        t.render()
    ))
}

/// A4: pool block granularity vs allocation latency + waste.
pub fn a4_pool_granularity(fast: bool) -> Result<String> {
    let iters = if fast { 5 } else { 20 };
    let expert_bytes = 9_437_184; // fp16 expert at qwen30b logical dims
    let capacity = 64 * expert_bytes;
    let mut t = Table::new(&[
        "block size", "alloc+free p50", "blocks/expert", "waste %",
    ]);
    for frac in [1.0, 0.5, 0.25, 0.0625] {
        let block = (expert_bytes as f64 * frac) as usize;
        let pool = BlockPool::new("a4", capacity, block);
        let bench = Bench::new(2, iters);
        let r = bench.run("alloc", || {
            let mut live = Vec::new();
            for _ in 0..32 {
                live.push(pool.alloc(expert_bytes).unwrap());
            }
            for a in live {
                pool.free(a);
            }
        });
        let blocks_per = crate::util::ceil_div(expert_bytes, block);
        let waste = (blocks_per * block) as f64 / expert_bytes as f64 - 1.0;
        t.row(&[
            format!("{:.2} MB", block as f64 / 1e6),
            crate::bench::human(r.p50_s / 64.0), // per alloc+free pair
            format!("{blocks_per}"),
            format!("{:.2}", waste * 100.0),
        ]);
    }
    Ok(format!(
        "== A4: pool granularity (fixed-size blocks, constant-time free \
         list) ==\n{}",
        t.render()
    ))
}

/// A5: static mixed-precision map under workload shift (numeric).
///
/// The paper's Observation 2 made concrete: an offline-calibrated
/// per-expert precision map (MxMoE/MoPEQ-class) matches DynaExq on its
/// calibration workload but misallocates its high-precision budget when
/// the workload shifts; DynaExq re-converges online.
#[cfg(feature = "numeric")]
pub fn a5_static_map_shift(fast: bool) -> Result<String> {
    use crate::experiments::quality_exp::{logical_n_hi, QualityFixture};
    use crate::quality::logit_kl;

    let (n_prompts, prompt_len) = if fast { (2, 32) } else { (4, 64) };
    let fixture = QualityFixture::new("phi-sim")?;
    let n_hi = logical_n_hi(&fixture.plan_preset, &ServingConfig::default())?;
    let calib = WorkloadProfile::text();
    let shifted = WorkloadProfile::code();
    let counts = fixture.calibrate_counts(&calib, n_prompts, prompt_len)?;
    let registry = BackendRegistry::with_builtins();
    // The map's hot capacity matches DynaExq's paper-scale plan; its counts
    // come from the real (numeric) calibration pass above.
    let mut map_cfg = ServingConfig::default();
    map_cfg.n_hi_override = Some(n_hi);

    let mut t = Table::new(&["method", "KL on text (calib)", "KL on code (shift)"]);
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for method in ["static-map", "dynaexq", "static"] {
        let mut kls = Vec::new();
        for w in [&calib, &shifted] {
            let (ref_logits, _) =
                fixture.eval("fp16", w, n_prompts, prompt_len, None)?;
            let (hyp, _) = if method == "static-map" {
                let b = registry
                    .build(
                        method,
                        &BackendCtx::new(
                            &fixture.exec_preset,
                            &map_cfg,
                            &DeviceConfig::default(),
                        )
                        .with_counts(&counts),
                    )
                    .map_err(|e| anyhow!(e))?;
                fixture.eval_backend(b, false, w, n_prompts, prompt_len)?
            } else {
                fixture.eval(method, w, n_prompts, prompt_len, Some(n_hi))?
            };
            let kl = ref_logits
                .iter()
                .zip(&hyp)
                .map(|(r, h)| logit_kl(r, h))
                .sum::<f64>()
                / n_prompts as f64;
            kls.push(kl);
        }
        rows.push((method.to_string(), kls[0], kls[1]));
        t.row(&[
            method.to_string(),
            format!("{:.5}", kls[0]),
            format!("{:.5}", kls[1]),
        ]);
    }
    // degradation factors for the summary line
    let deg = |r: &(String, f64, f64)| r.2 / r.1.max(1e-9);
    let map_deg = rows
        .iter()
        .find(|r| r.0 == "static-map")
        .map(deg)
        .unwrap_or(0.0);
    let dyn_deg = rows
        .iter()
        .find(|r| r.0 == "dynaexq")
        .map(deg)
        .unwrap_or(0.0);
    Ok(format!(
        "== A5: offline mixed-precision map vs DynaExq under workload \
         shift (phi-sim, n_hi={n_hi}, map calibrated on 'text') ==\n{}\
         shift degradation (KL ratio code/text): static-map {map_deg:.2}x, \
         dynaexq {dyn_deg:.2}x\n",
        t.render()
    ))
}

/// A5 needs the numeric engine; without the `numeric` feature it reports
/// how to get it instead of silently skipping.
#[cfg(not(feature = "numeric"))]
pub fn a5_static_map_shift(_fast: bool) -> Result<String> {
    Err(anyhow!(
        "A5 runs on the numeric engine; rebuild with `--features numeric` \
         (requires the PJRT runtime and AOT artifacts)"
    ))
}

/// A6: reactive mixed-precision caching (HOBBIT-class) vs DynaExq's
/// long-horizon policy: same envelope, same never-stall contract —
/// different occupants of the hi-precision slots.
pub fn a6_reactive_vs_policy(fast: bool) -> Result<String> {
    let rounds = if fast { 3 } else { 8 };
    let preset = ModelPreset::qwen30b_sim();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let registry = BackendRegistry::with_builtins();
    let mut t = Table::new(&[
        "policy", "hi-tier traffic %", "migrated GB", "tpop p99",
    ]);
    for which in ["dynaexq", "hobbit"] {
        let backend = registry
            .build(which, &BackendCtx::new(&preset, &cfg, &dev))
            .map_err(|e| anyhow!(e))?;
        let mut e = Engine::new(
            &preset,
            &WorkloadProfile::text(),
            backend,
            &dev,
            EngineConfig { max_batch: 32, seed: 0xA6, track_activation: false },
        );
        // alternate workloads to stress both adaptation and stability
        let profiles = [WorkloadProfile::text(), WorkloadProfile::math()];
        for r in 0..rounds {
            let w = &profiles[r % 2];
            e.set_profile(w);
            e.serve_uniform(w, 8, 128, 16);
        }
        t.row(&[
            which.to_string(),
            format!("{:.1}", e.backend.hi_fraction() * 100.0),
            format!("{:.2}", e.backend.migrated_bytes() as f64 / 1e9),
            format!("{:.4}", e.metrics.tpop.p99()),
        ]);
    }
    Ok(format!(
        "== A6: reactive (HOBBIT-class) vs long-horizon (DynaExq) hi-slot \
         policy under alternating workloads (qwen30b-sim) ==\n{}",
        t.render()
    ))
}

/// A7: open-loop serving (Poisson arrivals, continuous batching) — the
/// serving-framework regime beyond the paper's closed batches. Sweeps the
/// offered load; the saturation knee is where each method's queue diverges.
pub fn a7_load_sweep(fast: bool) -> Result<String> {
    use crate::util::XorShiftRng;
    use crate::workload::RequestGenerator;

    let n_requests = if fast { 24 } else { 64 };
    let rates: &[f64] =
        if fast { &[2.0, 8.0, 16.0] } else { &[2.0, 4.0, 8.0, 16.0, 32.0] };
    let mut out = String::from(
        "== A7: open-loop continuous batching (qwen30b-sim, prompt 256, \
         output 32, Poisson arrivals) ==\n",
    );
    let mut t = Table::new(&[
        "method", "req/s", "ttft avg", "ttft p99", "e2e p99", "tok/s",
    ]);
    for method in ["static", "dynaexq", "expertflow"] {
        for &rate in rates {
            let mut e = crate::experiments::helpers::engine(
                "qwen30b-sim",
                method,
                "text",
                0xA7,
                false,
            )?;
            crate::experiments::helpers::warm(
                &mut e,
                &WorkloadProfile::text(),
                if fast { 1 } else { 2 },
            );
            let mut gen =
                RequestGenerator::new(WorkloadProfile::text(), 0xA7);
            let mut rng = XorShiftRng::new(rate.to_bits());
            let mut now = e.now();
            let mut reqs = Vec::new();
            for _ in 0..n_requests {
                // exponential inter-arrival at `rate` req/s
                now += -rng.next_f64().max(1e-12).ln() / rate;
                reqs.push(gen.request(256, 32, now));
            }
            e.serve_stream(reqs);
            t.row(&[
                method.to_string(),
                format!("{rate}"),
                format!("{:.2}", e.metrics.ttft.avg()),
                format!("{:.2}", e.metrics.ttft.p99()),
                format!("{:.2}", e.metrics.e2e.p99()),
                format!("{:.0}", e.metrics.throughput()),
            ]);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

/// A8: tier count — the 2-rung hi/lo ladder vs the 3-rung
/// Fp16/Int4/Int2 ladder under the *same* HBM envelope (qwen30b-sim).
///
/// The middle rung gives warm experts an Int4 landing spot instead of the
/// Int2 base, trading some top-rung capacity for a deeper fidelity
/// gradient: the 3-rung run should serve a larger share of traffic above
/// the base rung while staying inside the identical envelope.
pub fn a8_tier_count(fast: bool) -> Result<String> {
    let rounds = if fast { 3 } else { 8 };
    let preset = ModelPreset::qwen30b_sim();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let registry = BackendRegistry::with_builtins();
    let mut t = Table::new(&[
        "ladder",
        "resident/rung",
        "traffic/rung %",
        "migrated GB",
        "tpop p99",
        "tok/s",
    ]);
    for method in ["dynaexq", "dynaexq-3tier"] {
        let backend = registry
            .build(method, &BackendCtx::new(&preset, &cfg, &dev))
            .map_err(|e| anyhow!(e))?;
        let mut e = Engine::new(
            &preset,
            &WorkloadProfile::text(),
            backend,
            &dev,
            EngineConfig { max_batch: 32, seed: 0xA8, track_activation: false },
        );
        let w = WorkloadProfile::text();
        for _ in 0..rounds {
            e.serve_uniform(&w, 8, 128, 16);
        }
        let joined = |xs: Vec<String>| xs.join("/");
        t.row(&[
            method.to_string(),
            joined(
                e.backend
                    .tier_residency()
                    .iter()
                    .map(|n| n.to_string())
                    .collect(),
            ),
            joined(
                e.backend
                    .tier_fractions()
                    .iter()
                    .map(|f| format!("{:.1}", f * 100.0))
                    .collect(),
            ),
            format!("{:.2}", e.backend.migrated_bytes() as f64 / 1e9),
            format!("{:.4}", e.metrics.tpop.p99()),
            format!("{:.0}", e.metrics.throughput()),
        ]);
    }
    Ok(format!(
        "== A8: tier count — 2-rung vs 3-rung ladder, identical HBM \
         envelope (qwen30b-sim, text workload) ==\n{}",
        t.render()
    ))
}

/// A9: device-group width — the same model and group-wide HBM envelope
/// served by 1-, 2-, and 4-device expert-sharded groups (DESIGN.md §9).
///
/// Sharding splits each layer's expert compute across per-device lanes
/// (throughput up) but also splits the envelope: every device waterfills
/// its own slack over its own shard, and promotions ride per-device
/// migration streams that contend on the host aggregate. The 1-device row
/// is byte-identical to plain `dynaexq` — the equivalence the group
/// construction guarantees.
pub fn a9_sharding(fast: bool) -> Result<String> {
    let rounds = if fast { 2 } else { 6 };
    let mut t = Table::new(&[
        "devices",
        "resident/rung/device",
        "promo-queue",
        "hi-tier %",
        "migrated GB",
        "tok/s",
    ]);
    for devices in [1usize, 2, 4] {
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq-sharded")
            .workload("text")
            .devices(devices)
            .seed(0xA9)
            .warmup(1)
            .build()?;
        for _ in 0..rounds {
            s.serve_closed(8, 128, 16)?;
        }
        let snap = s.snapshot();
        t.row(&[
            format!("{devices}"),
            crate::serving::session::MetricsSnapshot::encode_per_device(
                &snap.device_resident,
            ),
            snap.promo_queue_depth
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("/"),
            format!("{:.1}", snap.hi_fraction * 100.0),
            format!("{:.2}", snap.migrated_bytes as f64 / 1e9),
            format!("{:.0}", snap.throughput_tok_s),
        ]);
    }
    Ok(format!(
        "== A9: device-group width — expert-sharded serving under one \
         group-wide envelope (qwen30b-sim, dynaexq-sharded, text) ==\n{}",
        t.render()
    ))
}

/// A10: adaptive (drift-aware) vs fixed-α hotness under each scripted
/// scenario (DESIGN.md §10).
///
/// The drift layer should be a strict superset in behaviour: silent under
/// `steady` (no change-points, no extra churn) and reactive under `swap` /
/// `burst`, where the change-point drops α and rescales stale scores so
/// the waterfill re-converges in bounded update intervals. The drift
/// events / recovery-ticks columns are the numbers CI archives as the
/// drift-recovery report.
pub fn a10_adaptive_drift(fast: bool) -> Result<String> {
    let (prompt, output) = if fast { (64, 8) } else { (128, 16) };
    let mut t = Table::new(&[
        "scenario",
        "method",
        "drift events",
        "recovery ticks",
        "hi-tier %",
        "migrated GB",
        "tok/s",
    ]);
    for sc_name in ["steady", "swap", "rotation", "burst"] {
        let sc = crate::experiments::helpers::scenario(sc_name)?;
        for method in ["dynaexq", "dynaexq-adaptive"] {
            let mut s = ServeSession::builder()
                .model("qwen30b-sim")
                .method(method)
                .workload("text")
                .seed(0xA10)
                .warmup(1)
                .build()?;
            s.run_scenario(&sc, 8, prompt, output)?;
            let snap = s.snapshot();
            t.row(&[
                sc_name.to_string(),
                method.to_string(),
                format!("{}", snap.drift_events),
                format!("{}", snap.drift_recovery_ticks),
                format!("{:.1}", snap.hi_fraction * 100.0),
                format!("{:.2}", snap.migrated_bytes as f64 / 1e9),
                format!("{:.0}", snap.throughput_tok_s),
            ]);
        }
    }
    Ok(format!(
        "== A10: drift-aware (adaptive α) vs fixed-α hotness across \
         scripted workload scenarios (qwen30b-sim) ==\n{}",
        t.render()
    ))
}

/// A11: QoS class-weighted allocation — the per-class quality/throughput
/// frontier (DESIGN.md §15).
///
/// The multi-tenant scenario tags its tenant phases premium / standard /
/// best-effort. Sweeping the weight ladder from the degenerate
/// single-class config (structurally identical to the unweighted stack)
/// through increasingly skewed ladders traces the frontier: premium
/// traffic buys hi-precision resolve share with weight, paid for by the
/// best-effort class, while the envelope — not the weights — bounds the
/// aggregate hi capacity.
pub fn a11_qos_frontier(fast: bool) -> Result<String> {
    use crate::config::frontdoor::FrontDoorConfig;
    use crate::config::{QosClass, QosConfig};

    let (prompt, output) = if fast { (48, 6) } else { (128, 16) };
    let sc = crate::experiments::helpers::scenario("multi-tenant")?;
    let ladders: Vec<(&str, QosConfig)> = vec![
        ("degenerate", QosConfig::degenerate()),
        ("tiered 4/1/0.25", QosConfig::tiered()),
        (
            "skewed 8/1/0.1",
            QosConfig::degenerate()
                .with_weight(QosClass::Premium, 8.0)
                .with_weight(QosClass::BestEffort, 0.1),
        ),
    ];
    let mut t = Table::new(&[
        "ladder", "class", "weight", "hi-resolve %", "tok/s",
    ]);
    let mut tiered_shares = [0.0f64; 3];
    for (name, q) in &ladders {
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq")
            .workload("text")
            .seed(0xA11)
            .warmup(1)
            .frontdoor(FrontDoorConfig::default())
            .qos(q.clone())
            .build()?;
        s.run_scenario_frontdoor(&sc, 8, prompt, output)?;
        let snap = s.snapshot();
        if snap.qos_class_resolved.is_empty() {
            // the degenerate ladder collapses to the classless stack —
            // no per-class planes exist, so it reports one aggregate row
            t.row(&[
                name.to_string(),
                "(all)".into(),
                "1".into(),
                format!("{:.1}", snap.hi_fraction * 100.0),
                format!("{:.0}", snap.throughput_tok_s),
            ]);
            continue;
        }
        for class in QosClass::ALL {
            let row = &snap.qos_class_resolved[class.index()];
            let total: u64 = row.iter().sum();
            let share = row[0] as f64 / total.max(1) as f64;
            if *name == "tiered 4/1/0.25" {
                tiered_shares[class.index()] = share;
            }
            t.row(&[
                name.to_string(),
                class.name().into(),
                format!("{}", q.class(class).weight),
                format!("{:.1}", share * 100.0),
                format!("{:.0}", snap.throughput_tok_s),
            ]);
        }
    }
    let p = tiered_shares[QosClass::Premium.index()];
    let b = tiered_shares[QosClass::BestEffort.index()];
    Ok(format!(
        "== A11: QoS class-weighted allocation frontier (qwen30b-sim, \
         multi-tenant scenario through the front door) ==\n{}\
         tiered premium hi-resolve {p:.3} vs best-effort {b:.3} — premium \
         dominates = {}\n",
        t.render(),
        p > b
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_drift_ablation_covers_scenarios_and_methods() {
        let report = a10_adaptive_drift(true).unwrap();
        for sc in ["steady", "swap", "rotation", "burst"] {
            assert!(report.contains(sc), "missing scenario {sc}: {report}");
        }
        assert!(report.contains("dynaexq-adaptive"), "{report}");
        // the fixed-α rows never report drift events
        for line in report.lines().filter(|l| {
            l.contains("dynaexq ") && !l.contains("adaptive")
        }) {
            let cols: Vec<&str> = line.split_whitespace().collect();
            if let Some(i) = cols.iter().position(|c| *c == "dynaexq") {
                assert_eq!(cols[i + 1], "0", "fixed-α drift column: {line}");
            }
        }
    }

    #[test]
    fn qos_frontier_premium_dominates_best_effort() {
        // Acceptance: under the multi-tenant scenario the tiered ladder
        // gives premium traffic a strictly higher hi-precision resolve
        // share than best-effort, and the degenerate ladder reports the
        // single aggregate row of the classless stack.
        let report = a11_qos_frontier(true).unwrap();
        assert!(report.contains("premium dominates = true"), "{report}");
        assert!(report.contains("(all)"), "{report}");
        for class in ["premium", "standard", "best-effort"] {
            assert!(report.contains(class), "missing {class}: {report}");
        }
    }

    #[test]
    fn sharding_ablation_covers_group_widths() {
        let report = a9_sharding(true).unwrap();
        assert!(report.contains("devices"), "{report}");
        for d in ["1", "2", "4"] {
            assert!(
                report.lines().any(|l| l.trim_start().starts_with(d)),
                "missing {d}-device row: {report}"
            );
        }
        // the multi-device rows report per-device residency ('/'-joined)
        assert!(report.contains('/'), "{report}");
    }

    #[test]
    fn tier_count_ablation_runs_both_ladders() {
        let report = a8_tier_count(true).unwrap();
        assert!(report.contains("dynaexq-3tier"), "{report}");
        // the 3-rung row reports three per-rung residency counts
        let row3 = report
            .lines()
            .find(|l| l.contains("dynaexq-3tier"))
            .unwrap()
            .to_string();
        let counts_col = row3
            .split_whitespace()
            .find(|c| c.matches('/').count() == 2)
            .unwrap_or_else(|| panic!("no three-rung column in: {row3}"));
        assert_eq!(counts_col.split('/').count(), 3);
    }

    #[test]
    fn load_sweep_saturation_ordering() {
        // At high offered load the offloading baseline's queue must
        // diverge sooner than DynaExq's.
        let report = a7_load_sweep(true).unwrap();
        assert!(report.contains("expertflow"));
    }

    #[test]
    fn hysteresis_reduces_migration() {
        let (m0, _) = run_churn(0.0, 3, 0xEE).unwrap();
        let (m6, _) = run_churn(0.6, 3, 0xEE).unwrap();
        assert!(m6 <= m0, "margin 0.6 migrated {m6} > margin 0 {m0}");
    }

    #[test]
    fn blocking_hurts_latency() {
        let run = |blocking: bool| {
            let preset = ModelPreset::qwen30b_sim();
            let mut cfg = ServingConfig::default();
            cfg.blocking_transitions = blocking;
            let mut e = dynaexq_engine(&preset, cfg, 1).unwrap();
            let w = WorkloadProfile::text();
            for _ in 0..2 {
                e.serve_uniform(&w, 8, 128, 16);
            }
            e.metrics.e2e.avg()
        };
        assert!(run(true) >= run(false));
    }
}
