//! Figure 1: GPU waiting latency vs number of prompt tokens (ExpertFlow).
//!
//! Paper shape: waiting time grows with prompt length as prefill activation
//! densifies and swap traffic saturates PCIe; DynaExq/static show zero.

use anyhow::Result;

use crate::bench::Table;
use crate::workload::WorkloadProfile;

use super::helpers::{engine, warm};

pub const TOKEN_SWEEP: &[usize] = &[128, 256, 512, 1024, 2048, 4096];

/// Mean per-prefill waiting seconds for (method, prompt_len).
pub fn waiting_at(method: &str, prompt_len: usize, fast: bool) -> Result<f64> {
    let w = WorkloadProfile::text();
    let mut e = engine("qwen30b-sim", method, "text", 11, false)?;
    warm(&mut e, &w, if fast { 1 } else { 2 });
    e.serve_uniform(&w, 8, prompt_len, 4);
    Ok(e.metrics.wait.avg())
}

/// Figure 1 harness.
pub fn figure1_waiting(fast: bool) -> Result<String> {
    let sweep = if fast { &TOKEN_SWEEP[..4] } else { TOKEN_SWEEP };
    let mut headers = vec!["method"];
    let labels: Vec<String> =
        sweep.iter().map(|t| format!("{t} tok")).collect();
    headers.extend(labels.iter().map(String::as_str));
    let mut t = Table::new(&headers);
    for method in ["expertflow", "dynaexq", "static"] {
        let mut cells = vec![method.to_string()];
        for &len in sweep {
            cells.push(format!("{:.3}s", waiting_at(method, len, fast)?));
        }
        t.row(&cells);
    }
    Ok(format!(
        "== Figure 1: GPU waiting latency vs number of tokens \
         (qwen30b-sim, batch 8) ==\n{}",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expertflow_waits_grow_with_tokens() {
        let short = waiting_at("expertflow", 128, true).unwrap();
        let long = waiting_at("expertflow", 1024, true).unwrap();
        assert!(long > short, "long {long} vs short {short}");
        assert!(long > 0.0);
    }

    #[test]
    fn dynaexq_never_waits() {
        assert_eq!(waiting_at("dynaexq", 512, true).unwrap(), 0.0);
        assert_eq!(waiting_at("static", 512, true).unwrap(), 0.0);
    }
}
