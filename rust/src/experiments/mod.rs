//! Paper experiment harnesses.
//!
//! One function per table/figure of the paper's evaluation (DESIGN.md §5
//! maps each to its workload and modules). Both the CLI (`dynaexq report`)
//! and the bench targets (`cargo bench`) call into this module so every
//! number in EXPERIMENTS.md has exactly one implementation.

pub mod ablations;
pub mod activation;
pub mod helpers;
pub mod latency;
#[cfg(feature = "numeric")]
pub mod quality_exp;
pub mod shift;
pub mod waiting;

/// Without the `numeric` build feature the quality experiments cannot run
/// (no PJRT runtime); the harness entry points stay callable and explain
/// how to enable them.
#[cfg(not(feature = "numeric"))]
pub mod quality_exp {
    use anyhow::{bail, Result};

    const NO_NUMERIC: &str =
        "quality experiments run on the numeric engine; rebuild with \
         `--features numeric` (requires the PJRT runtime and AOT artifacts)";

    pub fn table4_quality(_fast: bool) -> Result<String> {
        bail!(NO_NUMERIC)
    }

    pub fn figure3_demotion(_fast: bool) -> Result<String> {
        bail!(NO_NUMERIC)
    }

    pub fn run_quality(
        _model: &str,
        _method: &str,
        _workload: &str,
        _n_prompts: usize,
        _prompt_len: usize,
    ) -> Result<crate::quality::QualityReport> {
        bail!(NO_NUMERIC)
    }
}

use anyhow::{anyhow, bail, Context, Result};

use crate::cli::Args;

/// Parse `--qos`: the canned `tiered` ladder (premium 4× / standard 1× /
/// best-effort 0.25×) or a `class=weight[:budget_bytes]` spec list
/// (DESIGN.md §15), e.g. `--qos premium=8:2000000000,best-effort=0.25`.
/// Returns `None` when the flag is absent; the degenerate single-class
/// config a spec can collapse to is still armed-off inside the stack.
fn parse_qos_arg(args: &Args) -> Result<Option<crate::config::QosConfig>> {
    use crate::config::QosConfig;
    let Some(spec) = args.get("qos") else {
        return Ok(None);
    };
    let q = if spec == "tiered" {
        QosConfig::tiered()
    } else {
        QosConfig::parse_spec(spec).map_err(|e| anyhow!("--qos: {e}"))?
    };
    Ok(Some(q))
}

/// `dynaexq serve` — one serving session on the builder API.
pub fn cmd_serve(args: &Args) -> Result<()> {
    // `--replicas N` (or any `--fail-replica` script, which implies a
    // fleet) routes to the replicated serving path (DESIGN.md §14).
    let replicas = args
        .get_parse::<usize>("replicas")
        .unwrap_or(if args.has("fail-replica") { 2 } else { 1 });
    if replicas > 1 {
        return cmd_serve_fleet(args, replicas);
    }
    if args.has("frontdoor") {
        return cmd_serve_frontdoor(args);
    }
    let model = args.get_or("model", "qwen30b-sim");
    let method = args.get_or("method", "dynaexq");
    let workload = args.get_or("workload", "text");
    let batch = args.get_parse::<usize>("batch").unwrap_or(8);
    let prompt = args.get_parse::<usize>("prompt").unwrap_or(512);
    let output = args.get_parse::<usize>("output").unwrap_or(64);
    let rounds = args.get_parse::<usize>("rounds").unwrap_or(4);
    let seed = args.get_parse::<u64>("seed").unwrap_or(0xC0FFEE);
    let warmup = args.get_parse::<usize>("warmup").unwrap_or(2);
    let devices = args.get_parse::<usize>("devices").unwrap_or(1);
    if let Some(sc_name) = args.get("scenario") {
        // Scripted-scenario serving (DESIGN.md §10): the scenario's phase
        // script supplies the workloads and round counts (`--workload` and
        // `--rounds` are ignored); per-phase boundary snapshots print as a
        // timeline, kv-encoded under --kv.
        let sc = helpers::scenario(sc_name)?;
        let mut builder = crate::serving::session::ServeSession::builder()
            .model(model)
            .method(method)
            .seed(seed)
            .warmup(warmup)
            .devices(devices);
        if let Some(q) = parse_qos_arg(args)? {
            // Class-weighted hotness only — without a front door there is
            // no budget ledger to charge.
            builder = builder.qos(q);
        }
        let mut session = builder.build()?;
        println!(
            "model {model} | method {method} | scenario {sc_name} \
             ({} phases, {} rounds) | batch {batch} prompt {prompt} \
             output {output}",
            sc.phases.len(),
            sc.total_rounds(),
        );
        let marks = session.run_scenario(&sc, batch, prompt, output)?;
        for (phase, snap) in &marks {
            println!(
                "phase {phase:<12} workload {:<5} | hi-tier {:>5.1}% | \
                 migrated {:>6.2} GB | drift {}x/{} ticks | {:>6.0} tok/s",
                snap.workload,
                snap.hi_fraction * 100.0,
                snap.migrated_bytes as f64 / 1e9,
                snap.drift_events,
                snap.drift_recovery_ticks,
                snap.throughput_tok_s,
            );
            if args.has("kv") {
                println!("{}", snap.encode());
            }
        }
        println!("{}", session.report());
        return Ok(());
    }
    if args.has("qos") {
        bail!(
            "--qos needs an allocation surface: add --frontdoor, \
             --scenario, or --replicas"
        );
    }
    let (session, report) = helpers::serve_session_with(
        model, method, workload, batch, prompt, output, rounds, seed, warmup,
        devices,
    )?;
    println!("{report}");
    if args.has("kv") {
        // machine-readable snapshot (MetricsSnapshot kv encoding)
        println!("{}", session.snapshot().encode());
    }
    Ok(())
}

/// `dynaexq serve --frontdoor` — the same session fronted by the bounded
/// admission queue (DESIGN.md §12): requests submit under round-robin
/// tenants/lanes (or the scenario's per-phase tags) and drain through the
/// SLO-aware scheduler; typed rejections and per-lane counters print with
/// the report.
fn cmd_serve_frontdoor(args: &Args) -> Result<()> {
    use crate::config::frontdoor::{FrontDoorConfig, Lane, TenantLimits};
    use crate::workload::RequestGenerator;

    let model = args.get_or("model", "qwen30b-sim");
    let method = args.get_or("method", "dynaexq");
    let workload = args.get_or("workload", "text");
    let batch = args.get_parse::<usize>("batch").unwrap_or(8);
    let prompt = args.get_parse::<usize>("prompt").unwrap_or(512);
    let output = args.get_parse::<usize>("output").unwrap_or(64);
    let rounds = args.get_parse::<usize>("rounds").unwrap_or(4);
    let seed = args.get_parse::<u64>("seed").unwrap_or(0xC0FFEE);
    let warmup = args.get_parse::<usize>("warmup").unwrap_or(2);
    let devices = args.get_parse::<usize>("devices").unwrap_or(1);
    let tenants = args.get_parse::<usize>("tenants").unwrap_or(2).max(1);

    let mut cfg = FrontDoorConfig::default();
    if let Some(spec) = args.get("slo") {
        cfg.classes = FrontDoorConfig::parse_slo_spec(spec)
            .map_err(anyhow::Error::msg)?;
    }
    if let Some(cap) = args.get_parse::<usize>("queue-cap") {
        cfg.queue_capacity = cap;
    }
    if let Some(cap) = args.get_parse::<usize>("tenant-cap") {
        cfg.tenant_limits =
            TenantLimits { soft_limit: cap, hard_limit: cap, ..cfg.tenant_limits };
    }

    let mut builder = crate::serving::session::ServeSession::builder()
        .model(model)
        .method(method)
        .workload(workload)
        .seed(seed)
        .warmup(warmup)
        .devices(devices)
        .frontdoor(cfg);
    if let Some(q) = parse_qos_arg(args)? {
        // Arms the door's budget ledger and the class-weighted hotness
        // fold together (the builder validates the spec against the HBM
        // envelope before anything is constructed).
        builder = builder.qos(q);
    }
    let mut session = builder.build()?;

    if let Some(sc_name) = args.get("scenario") {
        let sc = helpers::scenario(sc_name)?;
        println!(
            "model {model} | method {method} | scenario {sc_name} through \
             the front door ({} phases, {} rounds) | batch {batch} \
             prompt {prompt} output {output} | {tenants} tenants",
            sc.phases.len(),
            sc.total_rounds(),
        );
        let marks =
            session.run_scenario_frontdoor(&sc, batch, prompt, output)?;
        for (phase, snap) in &marks {
            println!(
                "phase {phase:<12} workload {:<5} | queue {} | admitted \
                 {} | rejected {} | deadline-miss {} | {:>6.0} tok/s",
                snap.workload,
                snap.fd_queue_depth,
                snap.fd_lane_admitted.iter().sum::<u64>(),
                snap.fd_lane_rejected.iter().sum::<u64>(),
                snap.fd_lane_deadline_miss.iter().sum::<u64>(),
                snap.throughput_tok_s,
            );
            if args.has("kv") {
                println!("{}", snap.encode());
            }
        }
        println!("{}", session.report());
        return Ok(());
    }

    // Uniform open-loop traffic: each round submits `batch` requests,
    // round-robin across `t0..t{N-1}` tenants and the three lanes, then
    // drains through the SLO scheduler.
    let profile = helpers::profile(workload)?;
    let mut gen = RequestGenerator::new(profile, seed ^ 0xFD01);
    let mut rejected = 0u64;
    let mut i = 0usize;
    for _ in 0..rounds {
        let now = session.now();
        for _ in 0..batch {
            let tenant = format!("t{}", i % tenants);
            let lane = Lane::ALL[i % Lane::ALL.len()];
            let req = gen.request(prompt, output, now);
            if session.submit(req, &tenant, lane)?.is_err() {
                rejected += 1;
            }
            i += 1;
        }
        session.drain()?;
    }
    println!("{}", session.report());
    if rejected > 0 {
        println!("typed rejections: {rejected}");
    }
    if args.has("kv") {
        println!("{}", session.snapshot().encode());
    }
    Ok(())
}

/// Parse a `--fail-replica` script: comma-separated `idx@round` entries,
/// each optionally followed by `:recover_round` (e.g. `0@2` downs replica
/// 0 from round 2 on; `0@2:5,1@7` also recovers it at round 5 and downs
/// replica 1 at round 7). Produces the deterministic [`FaultPlan`] the
/// fleet's modeled health checker polls each serve round.
fn parse_fault_spec(
    spec: &str,
    replicas: usize,
) -> Result<crate::workload::FaultPlan> {
    use crate::workload::{FaultEvent, FaultKind, FaultPlan};
    let mut plan = FaultPlan::none();
    for entry in spec.split(',').filter(|e| !e.is_empty()) {
        let (fail, recover) = match entry.split_once(':') {
            Some((f, r)) => (f, Some(r)),
            None => (entry, None),
        };
        let (idx, round) = fail.split_once('@').with_context(|| {
            format!("--fail-replica entry {entry:?}: expected idx@round")
        })?;
        let idx: usize = idx.trim().parse().with_context(|| {
            format!("--fail-replica entry {entry:?}: bad replica index")
        })?;
        let round: usize = round.trim().parse().with_context(|| {
            format!("--fail-replica entry {entry:?}: bad round")
        })?;
        if idx >= replicas {
            bail!(
                "--fail-replica entry {entry:?}: replica {idx} out of \
                 range (fleet has {replicas} replicas)"
            );
        }
        plan.push(FaultEvent { replica: idx, round, kind: FaultKind::Fail });
        if let Some(r) = recover {
            let r: usize = r.trim().parse().with_context(|| {
                format!("--fail-replica entry {entry:?}: bad recover round")
            })?;
            plan = plan.and_recover(idx, r);
        }
    }
    Ok(plan)
}

/// `dynaexq serve --replicas N` — a replicated fleet behind one shared
/// front door (DESIGN.md §14): load/affinity routing across N identical
/// engine replicas, a deterministic modeled health checker fed by the
/// `--fail-replica` script, and mid-stream failover that re-admits
/// stranded requests with token position preserved.
fn cmd_serve_fleet(args: &Args, replicas: usize) -> Result<()> {
    use crate::config::fleet::FleetConfig;
    use crate::serving::fleet::Fleet;

    let model = args.get_or("model", "qwen30b-sim");
    let method = args.get_or("method", "dynaexq");
    let workload = args.get_or("workload", "text");
    let batch = args.get_parse::<usize>("batch").unwrap_or(8);
    let prompt = args.get_parse::<usize>("prompt").unwrap_or(512);
    let output = args.get_parse::<usize>("output").unwrap_or(64);
    let seed = args.get_parse::<u64>("seed").unwrap_or(0xC0FFEE);
    let warmup = args.get_parse::<usize>("warmup").unwrap_or(2);
    let devices = args.get_parse::<usize>("devices").unwrap_or(1);

    let mut fc = FleetConfig::default();
    fc.replicas = replicas;
    fc.devices_per_replica = devices;
    // Chunked streaming (`--chunk N` decode rounds per serve round) keeps
    // requests in flight across rounds — the surface mid-stream failover
    // exercises. Without it each round serves to completion.
    fc.stream_chunk = args.get_parse::<usize>("chunk");
    fc.parallel_drain = args.has("parallel-drain");

    let faults = match args.get("fail-replica") {
        Some(spec) => parse_fault_spec(spec, replicas)?,
        None => crate::workload::FaultPlan::none(),
    };

    let mut builder = Fleet::builder()
        .model(model)
        .method(method)
        .workload(workload)
        .max_batch(batch)
        .seed(seed)
        .warmup(warmup)
        .fleet_cfg(fc)
        .faults(faults);
    if let Some(q) = parse_qos_arg(args)? {
        builder = builder.qos(q);
    }
    let mut fleet = builder.build()?;

    let sc_name = args.get_or("scenario", "steady");
    let sc = helpers::scenario(sc_name)?;
    println!(
        "model {model} | method {method} | fleet {replicas}x{devices} \
         replicas | scenario {sc_name} ({} phases, {} rounds) | batch \
         {batch} prompt {prompt} output {output}",
        sc.phases.len(),
        sc.total_rounds(),
    );
    let marks = fleet.run_scenario(&sc, batch, prompt, output)?;
    for (phase, snap) in &marks {
        println!(
            "phase {phase:<12} workload {:<5} | health {:?} | served \
             {:?} | failovers {} readmitted {} | {:>6.0} tok/s",
            snap.workload,
            snap.fleet_health,
            snap.fleet_served,
            snap.fleet_failovers,
            snap.fleet_readmitted,
            snap.throughput_tok_s,
        );
        if args.has("kv") {
            println!("{}", snap.encode());
        }
    }
    let snap = fleet.snapshot();
    let stats = fleet.stats();
    println!(
        "fleet: {} replicas | health {:?} | served per replica {:?} | \
         failovers {} | readmitted {} | admitted {} rejected {} | \
         decode {} tok",
        snap.fleet_replicas,
        snap.fleet_health,
        snap.fleet_served,
        stats.failovers,
        stats.readmitted,
        snap.fd_lane_admitted.iter().sum::<u64>(),
        snap.fd_lane_rejected.iter().sum::<u64>(),
        snap.decode_tokens,
    );
    if args.has("kv") {
        println!("{}", snap.encode());
    }
    Ok(())
}

/// `dynaexq bench` — the wall-clock serving benchmark matrix
/// (DESIGN.md §11): run method × scenario × devices × batch cells under
/// host wall-clock timing and emit the machine-readable
/// `BENCH_serving.json` perf trajectory.
pub fn cmd_bench(args: &Args) -> Result<()> {
    use crate::bench::runtime::{
        apply_filter, report_to_json, run_matrix, validate_report_json,
        BenchMatrix,
    };
    let smoke = args.has("smoke");
    // Smoke mode (CI) defaults to the small preset; the full matrix runs
    // the paper's headline model.
    let model =
        args.get_or("model", if smoke { "phi-sim" } else { "qwen30b-sim" });
    let out = args.get_or("out", "BENCH_serving.json");
    let mut matrix = if smoke {
        BenchMatrix::smoke(model)
    } else {
        BenchMatrix::full(model)
    };
    if let Some(p) = args.get_parse::<usize>("prompt") {
        matrix.prompt_len = p;
    }
    if let Some(o) = args.get_parse::<usize>("output") {
        matrix.output_len = o;
    }
    if let Some(s) = args.get_parse::<u64>("seed") {
        matrix.seed = s;
    }
    if let Some(p) = args.get_parse::<usize>("producers") {
        // Override the front-door producer-thread axis with a single
        // count (0 is meaningless — the knob only exists on cells that
        // have an admission path).
        if p == 0 {
            anyhow::bail!("--producers must be >= 1");
        }
        matrix.producers = vec![p];
    }
    if let Some(spec) = args.get("filter") {
        // Narrow to selected axis values (re-run single cells without
        // the full matrix); the written report stays schema-valid
        // because its header declares the narrowed axes.
        apply_filter(&mut matrix, spec)?;
    }
    println!(
        "bench: {} cells ({} methods × {} scenarios × {:?} devices × \
         {:?} batches × {:?} frontdoor × {:?} producers × {:?} replicas) \
         on {model}",
        matrix.n_cells(),
        matrix.methods.len(),
        matrix.scenarios.len(),
        matrix.devices,
        matrix.batches,
        matrix.frontdoor,
        matrix.producers,
        matrix.replicas,
    );
    let report = run_matrix(&matrix, |line| eprintln!("{line}"))?;
    println!("{}", crate::bench::runtime::render_table(&report));
    let json = report_to_json(&report);
    // Self-check the schema contract before anything consumes the file.
    validate_report_json(&json)?;
    std::fs::write(out, &json)
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {} cells to {out}", report.cells.len());
    Ok(())
}

/// `dynaexq report --exp <id>` — regenerate a paper table/figure.
pub fn cmd_report(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let fast = args.has("fast");
    let run = |id: &str| -> Result<String> {
        Ok(match id {
            "t1" => activation::table1_decode(fast)?,
            "t2" => activation::table2_prefill(fast)?,
            "t4" => quality_exp::table4_quality(fast)?,
            "f1" => waiting::figure1_waiting(fast)?,
            "f2" => shift::figure2_shift(fast)?,
            "f3" => quality_exp::figure3_demotion(fast)?,
            "f6" => latency::figure_batch_sweep("f6", fast)?,
            "f7" => latency::figure_batch_sweep("f7", fast)?,
            "f8" => latency::figure_batch_sweep("f8", fast)?,
            "f9" => latency::figure_batch_sweep("f9", fast)?,
            "f10" => latency::figure10_prompt_sweep(fast)?,
            "a1" => ablations::a1_hysteresis(fast)?,
            "a2" => ablations::a2_ema_alpha(fast)?,
            "a3" => ablations::a3_blocking(fast)?,
            "a4" => ablations::a4_pool_granularity(fast)?,
            "a5" => ablations::a5_static_map_shift(fast)?,
            "a6" => ablations::a6_reactive_vs_policy(fast)?,
            "a7" => ablations::a7_load_sweep(fast)?,
            "a8" => ablations::a8_tier_count(fast)?,
            "a9" => ablations::a9_sharding(fast)?,
            "a10" => ablations::a10_adaptive_drift(fast)?,
            "a11" => ablations::a11_qos_frontier(fast)?,
            other => bail!("unknown experiment {other:?}"),
        })
    };
    if exp == "all" {
        // Numeric-engine experiments (f3, t4, a5) need the `numeric`
        // feature; `all` skips them with a note in feature-less builds
        // instead of failing the whole report.
        let numeric = cfg!(feature = "numeric");
        for id in [
            "t1", "t2", "f1", "f2", "f3", "t4", "f6", "f7", "f8", "f9",
            "f10", "a1", "a2", "a3", "a4", "a5", "a6", "a7", "a8", "a9",
            "a10", "a11",
        ] {
            if !numeric && matches!(id, "f3" | "t4" | "a5") {
                println!(
                    "== {id} skipped: needs `--features numeric` (PJRT) ==\n"
                );
                continue;
            }
            println!("{}", run(id)?);
        }
    } else {
        println!("{}", run(exp)?);
    }
    Ok(())
}

/// `dynaexq quality` — a single numeric quality run.
pub fn cmd_quality(args: &Args) -> Result<()> {
    let model = args.get_or("model", "phi-sim");
    let method = args.get_or("method", "dynaexq");
    let prompts = args.get_parse::<usize>("prompts").unwrap_or(8);
    let prompt_len = args.get_parse::<usize>("prompt-len").unwrap_or(64);
    let workload = args.get_or("workload", "text");
    let r = quality_exp::run_quality(model, method, workload, prompts, prompt_len)?;
    println!(
        "{model}/{method}/{workload}: ppl {:.3}  KL {:.5}  relerr {:.4}  \
         agree {:.3}  ({} prompts)",
        r.perplexity, r.kl_vs_fp16, r.rel_err_vs_fp16, r.agreement_vs_fp16, r.prompts
    );
    Ok(())
}

/// `dynaexq trace` — routing-trace statistics, recording, and replay.
pub fn cmd_trace(args: &Args) -> Result<()> {
    let model = args.get_or("model", "qwen30b-sim");
    let workload = args.get_or("workload", "text");
    // One parse of --iters; it means total iterations (default 500), or
    // iterations per scenario round under `--record --scenario` (default
    // 8 — canned scenarios span tens of rounds).
    let iters_flag = args.get_parse::<usize>("iters");
    let iters = iters_flag.unwrap_or(500);

    if let Some(path) = args.get("record") {
        // Synthesize + persist a router trace for offline experiments.
        // `--scenario <name>` records a scripted multi-phase scenario
        // instead of one stationary workload (`--iters` then counts
        // iterations per scenario round).
        let p = helpers::preset(model)?;
        let batch = args.get_parse::<usize>("batch").unwrap_or(8);
        let seed = args.get_parse::<u64>("seed").unwrap_or(1);
        let (trace, what) = if let Some(sc_name) = args.get("scenario") {
            let sc = helpers::scenario(sc_name)?;
            let iters_per_round = iters_flag.unwrap_or(8);
            let t = sc.synthesize_trace(
                p.n_layers_logical(),
                p.n_experts,
                p.top_k,
                batch,
                iters_per_round,
                seed,
            );
            let total = sc.total_rounds() * iters_per_round;
            (t, format!("scenario {sc_name} ({total} iterations)"))
        } else {
            let w = helpers::profile(workload)?;
            let t = crate::workload::traces::synthesize(
                &w,
                p.n_layers_logical(),
                p.n_experts,
                p.top_k,
                batch,
                iters,
                seed,
            );
            (t, format!("workload {workload} ({iters} iterations)"))
        };
        trace.save(std::path::Path::new(path))?;
        println!(
            "recorded {} selections from {what} to {path}",
            trace.selections()
        );
        return Ok(());
    }
    if let Some(path) = args.get("replay") {
        // Replay a trace through a residency backend; report its behaviour.
        // `--workload` names the trace's workload, which is also the
        // calibration input for offline-calibrated methods (static-map).
        // `--devices N` replays through an N-device sharded group.
        let p = helpers::preset(model)?;
        let w = helpers::profile(workload)?;
        let method = args.get_or("method", "dynaexq");
        let devices = args.get_parse::<usize>("devices").unwrap_or(1);
        let cfg = crate::config::ServingConfig::default();
        let dev = crate::config::DeviceConfig::default();
        let mut backend = helpers::backend_with_devices(
            method,
            &p,
            &cfg,
            &dev,
            Some(&w),
            devices,
        )?;
        let trace =
            crate::workload::Trace::load(std::path::Path::new(path))?;
        // A mismatched trace would index out of range inside the backend's
        // residency tables — refuse it with a clear error instead.
        trace.check_matches(p.n_layers_logical(), p.n_experts)?;
        let tick_s = args
            .get_parse::<f64>("tick-ms")
            .unwrap_or(cfg.update_interval_ms)
            / 1e3;
        let end = trace.replay(backend.as_mut(), tick_s);
        println!(
            "replayed {} selections through {method}: modeled {end:.2}s, \
             hi-tier {:.1}%, migrated {:.2} GB",
            trace.selections(),
            backend.hi_fraction() * 100.0,
            backend.migrated_bytes() as f64 / 1e9,
        );
        return Ok(());
    }
    println!("{}", shift::trace_stats(model, workload, iters)?);
    Ok(())
}
