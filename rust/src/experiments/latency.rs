//! Figures 6–10: TTFT / TPOP / end-to-end latency / throughput vs batch
//! size, and TTFT vs prompt length.
//!
//! Paper shape: static quantization lowest (no weight movement), ExpertFlow
//! highest with the gap widening as batch/prompt grows (densification →
//! transfer pressure → visible waiting), DynaExq in between and close to
//! static; throughput 1.42–2.73× over ExpertFlow at batch 32.

use anyhow::Result;

use crate::bench::Table;
use crate::metrics::ServingMetrics;
use crate::workload::WorkloadProfile;

use super::helpers::{engine, warm, BATCHES, METHODS};

const MODELS: &[&str] = &["qwen30b-sim", "qwen80b-sim", "phi-sim"];

/// Run one (model, method, batch, prompt, output) config and return its
/// converged metrics.
pub fn run_config(
    model: &str,
    method: &str,
    batch: usize,
    prompt: usize,
    output: usize,
    fast: bool,
) -> Result<ServingMetrics> {
    run_config_seeded(
        model,
        method,
        batch,
        prompt,
        output,
        fast,
        0x5EED ^ batch as u64,
    )
}

/// [`run_config`] with an explicitly pinned engine/workload seed. The
/// request stream, the routing sampler, and (with staging synced at
/// iteration boundaries) the whole modeled run derive from this one seed
/// through `util::rng` — two calls with the same arguments are
/// byte-identical, so tests can assert tight bands instead of slack ones.
pub fn run_config_seeded(
    model: &str,
    method: &str,
    batch: usize,
    prompt: usize,
    output: usize,
    fast: bool,
    seed: u64,
) -> Result<ServingMetrics> {
    let w = WorkloadProfile::text();
    let mut e = engine(model, method, "text", seed, false)?;
    warm(&mut e, &w, if fast { 1 } else { 2 });
    let rounds = if fast { 1 } else { 2 };
    for _ in 0..rounds {
        e.serve_uniform(&w, batch, prompt, output);
    }
    Ok(e.metrics.clone())
}

/// Figures 6 (TTFT), 7 (TPOP), 8 (E2E latency), 9 (throughput): batch sweep.
pub fn figure_batch_sweep(which: &str, fast: bool) -> Result<String> {
    let (title, extract): (&str, fn(&ServingMetrics) -> String) = match which {
        "f6" => ("Figure 6: TTFT (avg/p99 s) vs batch size", |m| {
            format!("{:.2}/{:.2}", m.ttft.avg(), m.ttft.p99())
        }),
        "f7" => ("Figure 7: TPOP (avg/p99 s) vs batch size", |m| {
            format!("{:.4}/{:.4}", m.tpop.avg(), m.tpop.p99())
        }),
        "f8" => ("Figure 8: end-to-end latency (avg/p99 s) vs batch size", |m| {
            format!("{:.2}/{:.2}", m.e2e.avg(), m.e2e.p99())
        }),
        "f9" => ("Figure 9: end-to-end throughput (tokens/s) vs batch size", |m| {
            format!("{:.0}", m.throughput())
        }),
        other => anyhow::bail!("unknown sweep {other:?}"),
    };
    let batches = if fast { &BATCHES[..4] } else { BATCHES };
    let (prompt, output) = if fast { (128, 16) } else { (512, 64) };
    let mut out = format!("== {title} (prompt {prompt}, output {output}) ==\n");
    for model in MODELS {
        let mut headers = vec!["method"];
        let labels: Vec<String> =
            batches.iter().map(|b| format!("bs={b}")).collect();
        headers.extend(labels.iter().map(String::as_str));
        let mut t = Table::new(&headers);
        for method in METHODS {
            let mut cells = vec![method.to_string()];
            for &b in batches {
                let m = run_config(model, method, b, prompt, output, fast)?;
                cells.push(extract(&m));
            }
            t.row(&cells);
        }
        out.push_str(&format!("-- {model} --\n{}", t.render()));
    }
    if which == "f9" {
        // headline: DynaExq / ExpertFlow speedup at the largest batch
        let b = *batches.last().unwrap();
        for model in MODELS {
            let dy = run_config(model, "dynaexq", b, prompt, output, fast)?
                .throughput();
            let ef = run_config(model, "expertflow", b, prompt, output, fast)?
                .throughput();
            out.push_str(&format!(
                "{model}: DynaExq/ExpertFlow throughput at bs={b}: {:.2}x\n",
                dy / ef
            ));
        }
    }
    Ok(out)
}

/// Figure 10: TTFT (avg/p99) vs prompt length at batch 8.
pub fn figure10_prompt_sweep(fast: bool) -> Result<String> {
    let sweep: &[usize] = if fast {
        &[128, 512, 1024]
    } else {
        &[64, 128, 256, 512, 1024, 2048, 4096]
    };
    let mut out = String::from(
        "== Figure 10: TTFT (avg/p99 s) vs prompt length (batch 8) ==\n",
    );
    for model in MODELS {
        let mut headers = vec!["method"];
        let labels: Vec<String> =
            sweep.iter().map(|t| format!("{t}tok")).collect();
        headers.extend(labels.iter().map(String::as_str));
        let mut t = Table::new(&headers);
        for method in METHODS {
            let mut cells = vec![method.to_string()];
            for &len in sweep {
                let m = run_config(model, method, 8, len, 4, fast)?;
                cells.push(format!(
                    "{:.2}/{:.2}",
                    m.ttft.avg(),
                    m.ttft.p99()
                ));
            }
            t.row(&cells);
        }
        out.push_str(&format!("-- {model} --\n{}", t.render()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_static_dynaexq_expertflow() {
        // The paper's headline ordering at a non-trivial batch.
        let st = run_config("qwen30b-sim", "static", 8, 128, 8, true).unwrap();
        let dy = run_config("qwen30b-sim", "dynaexq", 8, 128, 8, true).unwrap();
        let ef =
            run_config("qwen30b-sim", "expertflow", 8, 128, 8, true).unwrap();
        assert!(
            st.ttft.avg() <= dy.ttft.avg() * 1.05,
            "static {} ≤ dynaexq {}",
            st.ttft.avg(),
            dy.ttft.avg()
        );
        assert!(
            dy.ttft.avg() < ef.ttft.avg(),
            "dynaexq {} < expertflow {}",
            dy.ttft.avg(),
            ef.ttft.avg()
        );
        assert!(dy.throughput() > ef.throughput());
    }

    #[test]
    fn expertflow_gap_widens_with_batch() {
        let gap = |b: usize| {
            let dy =
                run_config("qwen30b-sim", "dynaexq", b, 64, 8, true).unwrap();
            let ef = run_config("qwen30b-sim", "expertflow", b, 64, 8, true)
                .unwrap();
            ef.ttft.avg() / dy.ttft.avg()
        };
        assert!(gap(16) > gap(1), "gap(16)={} gap(1)={}", gap(16), gap(1));
    }
}
