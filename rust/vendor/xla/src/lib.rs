//! Stub of the XLA/PJRT binding surface used by `dynaexq::runtime` — see
//! README.md. Compiles the numeric stack offline; every device entry
//! point errors at run time ([`PjRtClient::cpu`] fails first, so nothing
//! downstream executes against the stub).

/// The error type the bindings surface (`{e:?}`-formatted by callers).
pub struct Error(&'static str);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

const STUB: &str = "xla stub: built without the real XLA/PJRT bindings — \
                    see rust/vendor/xla/README.md to wire them in";

fn unavailable<T>() -> Result<T> {
    Err(Error(STUB))
}

/// Element types of untyped literal constructors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    U8,
    S32,
    F32,
}

/// A host-side literal (opaque in the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// A parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }

    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// The PJRT client. `cpu()` always errors in the stub, which is the single
/// gate keeping the rest of this API unreachable at run time.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(format!("{err:?}").contains("xla stub"));
    }
}
