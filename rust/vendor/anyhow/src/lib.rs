//! A minimal, dependency-free stand-in for the [`anyhow`] crate, vendored
//! so the workspace builds with **zero network access** (the repo's
//! offline crate set — the same reason `config::kv` replaces serde).
//!
//! It implements exactly the surface this codebase uses — `Error`,
//! `Result`, `anyhow!`, `bail!`, and the `Context` extension trait — with
//! the same call-site semantics, so swapping in the real crate is a
//! one-line change in `rust/Cargo.toml`. Differences from the real thing:
//! no backtraces, no downcasting, and `Display` always prints the full
//! context chain (real `anyhow` reserves that for `{:#}`).
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// A string-chained error: the outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message (the `anyhow!` /
    /// `Error::msg` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

// `?` conversion from any std error (mirrors real anyhow's blanket impl;
// no overlap with the reflexive `From<Error>` because `Error` itself does
// not implement `std::error::Error`, exactly like the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as context layers.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Errors that can become [`crate::Error`] when context is attached:
    /// `crate::Error` itself plus every std error.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }
}

/// Context-attachment extension for `Result` and `Option` (the subset of
/// real anyhow's `Context` this workspace uses).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root cause {}", 42)
    }

    #[test]
    fn macros_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root cause 42");
        let e = anyhow!("plain");
        assert_eq!(format!("{e:?}"), "plain");
        let owned: Error = anyhow!(String::from("from-string"));
        assert_eq!(owned.to_string(), "from-string");
    }

    #[test]
    fn io_errors_convert_and_wrap() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")
                .with_context(|| format!("reading {}", "/definitely"))?;
            Ok(s)
        }
        let e = read().unwrap_err().to_string();
        assert!(e.starts_with("reading /definitely: "), "{e}");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(
            none.context("empty prompt").unwrap_err().to_string(),
            "empty prompt"
        );
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }
}
