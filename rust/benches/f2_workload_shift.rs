//! Paper Figure 2: workload-dependent hot sets (heavy tail + disjoint top-10).
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp f2`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::shift::figure2_shift(fast)?);
    Ok(())
}
