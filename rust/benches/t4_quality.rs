//! Paper Table 4: quality across models and methods (numeric proxy suite).
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp t4`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::quality_exp::table4_quality(fast)?);
    Ok(())
}
