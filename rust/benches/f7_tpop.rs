//! Paper Figure 7: TPOP (avg/P99) vs batch size, three models × methods.
//! Same code path as `dynaexq report --exp f7`. DYNAEXQ_FULL=1 for full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::latency::figure_batch_sweep("f7", fast)?);
    Ok(())
}
