//! Microbenchmarks of the L3 hot path (perf pass, DESIGN.md §7):
//! handle resolution, routing recording, policy update, pool ops, and one
//! real PJRT expert execution.

use dynaexq::bench::Bench;
use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::{BlockPool, Coordinator};

fn main() -> anyhow::Result<()> {
    let bench = Bench::new(3, 30);
    let preset = ModelPreset::qwen30b_sim();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let coord = Coordinator::new(&preset, &cfg, &dev).map_err(anyhow::Error::msg)?;

    // 1. stable-handle resolution (the per-expert hot-path read)
    let r = bench.run("resolve × 10k", || {
        for e in 0..128usize {
            for l in 0..48usize {
                std::hint::black_box(coord.resolve(l, e % 128));
            }
        }
        for _ in 0..(10_000 - 128 * 48) {
            std::hint::black_box(coord.resolve(0, 7));
        }
    });
    println!("{}   ({:.1} ns/resolve)", r.line(), r.mean_s * 1e9 / 1e4);

    // 2. routing recording (per-iteration router trace ingestion)
    let experts: Vec<usize> = (0..256).map(|i| i % 128).collect();
    let r = bench.run("record_routing 256 sel × 48 layers", || {
        for l in 0..48 {
            coord.record_routing(l, &experts);
        }
    });
    println!("{}", r.line());

    // 2b. batched routing ingestion — the iteration-boundary flush path
    //     the serving backends now use: one hotness lock per boundary
    //     instead of one per layer (DESIGN.md §11).
    let r = bench.run("record_layers 256 sel × 48 layers (1 lock)", || {
        coord.record_layers((0..48).map(|l| (l, experts.as_slice())));
    });
    println!("{}", r.line());

    // 2c. scratch-buffer top-k sampling vs the allocating path (the
    //     engine's per-token inner loop).
    let sampler = dynaexq::workload::RoutingSampler::new(
        &dynaexq::workload::WorkloadProfile::text(),
        48,
        128,
        8,
    );
    let mut rng = dynaexq::util::XorShiftRng::new(7);
    let r = bench.run("sample_topk (alloc) × 4k", || {
        for tag in 0..4_000u64 {
            std::hint::black_box(sampler.sample_topk(&mut rng, tag, 0));
        }
    });
    println!("{}", r.line());
    let mut picked = Vec::new();
    let r = bench.run("sample_topk_into (scratch) × 4k", || {
        for tag in 0..4_000u64 {
            sampler.sample_topk_into(&mut rng, tag, 0, &mut picked);
            std::hint::black_box(&picked);
        }
    });
    println!("{}", r.line());

    // 2d. sharded recording under contention — 4 producer threads racing
    //     the lock-free count shards (DESIGN.md §13). Compare against 2:
    //     the per-thread cost should stay flat because producers never
    //     take a lock.
    let r = bench.run("record_routing 256 sel × 48 layers × 4 threads", || {
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for l in 0..48 {
                        coord.record_routing(l, &experts);
                    }
                });
            }
        });
    });
    println!("{}", r.line());

    // 3. full policy update (48 layers × 128 experts)
    let mut now = 1.0;
    let r = bench.run("policy tick (48×128)", || {
        now += 1.0;
        std::hint::black_box(coord.tick(now));
    });
    println!("{}", r.line());

    // 3b. concurrent group tick — a 2-device group with both updates due,
    //     walked on scoped threads (serial gate vs parallel walk is the
    //     delta this measures; see DeviceGroup::tick).
    let group = dynaexq::coordinator::DeviceGroup::new(&preset, &cfg, &dev, 2)
        .map_err(anyhow::Error::msg)?;
    let mut gnow = 1.0;
    let r = bench.run("group tick 2dev (concurrent)", || {
        for l in 0..48 {
            group.record_routing(l, &experts);
        }
        gnow += 1.0;
        std::hint::black_box(group.tick(gnow));
    });
    println!("{}", r.line());

    // 4. pool alloc/free
    let pool = BlockPool::new("bench", 128 << 20, 1 << 20);
    let r = bench.run("pool alloc+free × 1k", || {
        for _ in 0..1000 {
            let a = pool.alloc(1 << 20).unwrap();
            pool.free(a);
        }
    });
    println!("{}   ({:.1} ns/pair)", r.line(), r.mean_s * 1e9 / 1e3);

    // 5/6. real PJRT expert execution (the numeric hot path)
    pjrt_microbenches(&bench)?;
    Ok(())
}

#[cfg(not(feature = "numeric"))]
fn pjrt_microbenches(_bench: &Bench) -> anyhow::Result<()> {
    println!("(built without --features numeric — skipping PJRT microbenches)");
    Ok(())
}

/// Real PJRT expert execution (the numeric hot path).
#[cfg(feature = "numeric")]
fn pjrt_microbenches(bench: &Bench) -> anyhow::Result<()> {
    use dynaexq::util::XorShiftRng;
    use std::sync::Arc;

    if let Ok(rt) = dynaexq::runtime::Runtime::load_default() {
        let rt = Arc::new(rt);
        let mut rng = XorShiftRng::new(1);
        let d = dynaexq::config::D_MODEL;
        let f = dynaexq::config::FF_DIM;
        let x: Vec<f32> = (0..16 * d).map(|_| rng.normal_f32()).collect();
        let w: Vec<f32> = (0..d * f).map(|_| rng.normal_f32() * 0.1).collect();
        let w2: Vec<f32> = (0..f * d).map(|_| rng.normal_f32() * 0.1).collect();
        let xl = dynaexq::runtime::lit_f32(&x, &[16, d as i64])?;
        let w1l = dynaexq::runtime::lit_f32(&w, &[d as i64, f as i64])?;
        let w3l = dynaexq::runtime::lit_f32(&w, &[d as i64, f as i64])?;
        let w2l = dynaexq::runtime::lit_f32(&w2, &[f as i64, d as i64])?;
        rt.executable("expert_fp16_t16")?; // compile outside timing
        let r = bench.run("PJRT expert_fp16_t16 execute", || {
            std::hint::black_box(
                rt.execute_refs("expert_fp16_t16", &[&xl, &w1l, &w3l, &w2l])
                    .unwrap(),
            );
        });
        println!("{}", r.line());

        let q = dynaexq::model::quant::quantize(
            &w,
            d,
            f,
            dynaexq::model::Precision::Int4,
        );
        let q2 = dynaexq::model::quant::quantize(
            &w2,
            f,
            d,
            dynaexq::model::Precision::Int4,
        );
        let args = [
            dynaexq::runtime::lit_u8(&q.data, &[(d / 2) as i64, f as i64])?,
            dynaexq::runtime::lit_f32(&q.scales, &[f as i64])?,
            dynaexq::runtime::lit_u8(&q.data, &[(d / 2) as i64, f as i64])?,
            dynaexq::runtime::lit_f32(&q.scales, &[f as i64])?,
            dynaexq::runtime::lit_u8(&q2.data, &[(f / 2) as i64, d as i64])?,
            dynaexq::runtime::lit_f32(&q2.scales, &[d as i64])?,
        ];
        rt.executable("expert_int4_t16")?;
        let r = bench.run("PJRT expert_int4_t16 execute", || {
            std::hint::black_box(
                rt.execute_refs(
                    "expert_int4_t16",
                    &[&xl, &args[0], &args[1], &args[2], &args[3], &args[4], &args[5]],
                )
                .unwrap(),
            );
        });
        println!("{}", r.line());

        // 6. buffer-based execution: weights staged on device once, only
        //    the activation moves per call (the perf-pass optimization).
        let wb1 = rt.buffer_f32(&w, &[d, f])?;
        let wb3 = rt.buffer_f32(&w, &[d, f])?;
        let wb2 = rt.buffer_f32(&w2, &[f, d])?;
        let r = bench.run("PJRT expert_fp16_t16 execute_b (staged w)", || {
            let xb = rt.buffer_f32(&x, &[16, d]).unwrap();
            std::hint::black_box(
                rt.execute_buffers(
                    "expert_fp16_t16",
                    &[&xb, &wb1, &wb3, &wb2],
                )
                .unwrap(),
            );
        });
        println!("{}", r.line());

        let qw1 = rt.buffer_u8(&q.data, &[d / 2, f])?;
        let qs1 = rt.buffer_f32(&q.scales, &[f])?;
        let qw3 = rt.buffer_u8(&q.data, &[d / 2, f])?;
        let qs3 = rt.buffer_f32(&q.scales, &[f])?;
        let qw2 = rt.buffer_u8(&q2.data, &[f / 2, d])?;
        let qs2 = rt.buffer_f32(&q2.scales, &[d])?;
        let r = bench.run("PJRT expert_int4_t16 execute_b (staged w)", || {
            let xb = rt.buffer_f32(&x, &[16, d]).unwrap();
            std::hint::black_box(
                rt.execute_buffers(
                    "expert_int4_t16",
                    &[&xb, &qw1, &qs1, &qw3, &qs3, &qw2, &qs2],
                )
                .unwrap(),
            );
        });
        println!("{}", r.line());
    } else {
        println!("(artifacts missing — skipping PJRT microbenches)");
    }
    Ok(())
}
