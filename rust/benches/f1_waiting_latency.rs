//! Paper Figure 1: GPU waiting latency vs number of prompt tokens.
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp f1`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::waiting::figure1_waiting(fast)?);
    Ok(())
}
