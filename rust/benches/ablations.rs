//! Ablations A1–A6 (DESIGN.md §6): hysteresis, EMA alpha / update interval,
//! blocking vs non-blocking transitions, pool granularity, static
//! mixed-precision map under shift, reactive vs long-horizon policy.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    use dynaexq::experiments::ablations as a;
    println!("{}", a::a1_hysteresis(fast)?);
    println!("{}", a::a2_ema_alpha(fast)?);
    println!("{}", a::a3_blocking(fast)?);
    println!("{}", a::a4_pool_granularity(fast)?);
    println!("{}", a::a5_static_map_shift(fast)?);
    println!("{}", a::a6_reactive_vs_policy(fast)?);
    println!("{}", a::a7_load_sweep(fast)?);
    Ok(())
}
