//! Ablations A1–A8 (DESIGN.md §6): hysteresis, EMA alpha / update interval,
//! blocking vs non-blocking transitions, pool granularity, static
//! mixed-precision map under shift, reactive vs long-horizon policy,
//! open-loop load sweep, tier count.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    use dynaexq::experiments::ablations as a;
    println!("{}", a::a1_hysteresis(fast)?);
    println!("{}", a::a2_ema_alpha(fast)?);
    println!("{}", a::a3_blocking(fast)?);
    println!("{}", a::a4_pool_granularity(fast)?);
    // A5 needs the numeric engine (`--features numeric`).
    match a::a5_static_map_shift(fast) {
        Ok(report) => println!("{report}"),
        Err(e) => println!("(a5 skipped: {e})\n"),
    }
    println!("{}", a::a6_reactive_vs_policy(fast)?);
    println!("{}", a::a7_load_sweep(fast)?);
    println!("{}", a::a8_tier_count(fast)?);
    Ok(())
}
