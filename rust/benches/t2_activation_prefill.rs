//! Paper Table 2: expert activation ratio (%) in prefill vs batch size.
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp t2`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::activation::table2_prefill(fast)?);
    Ok(())
}
