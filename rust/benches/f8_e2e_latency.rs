//! Paper Figure 8: end-to-end latency vs batch size, three models × methods.
//! Same code path as `dynaexq report --exp f8`. DYNAEXQ_FULL=1 for full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::latency::figure_batch_sweep("f8", fast)?);
    Ok(())
}
