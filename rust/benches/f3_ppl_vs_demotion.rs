//! Paper Figure 3: perplexity vs demoted cold experts per layer (numeric).
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp f3`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::quality_exp::figure3_demotion(fast)?);
    Ok(())
}
