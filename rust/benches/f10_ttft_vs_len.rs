//! Paper Figure 10: TTFT vs prompt length (batch 8).
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp f10`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::latency::figure10_prompt_sweep(fast)?);
    Ok(())
}
