//! Paper Table 1: expert activation ratio (%) in decode vs batch size.
//! Thin wrapper over `dynaexq::experiments` — the same code path as
//! `dynaexq report --exp t1`. Set DYNAEXQ_FULL=1 for the full sweep.

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DYNAEXQ_FULL").is_err();
    println!("{}", dynaexq::experiments::activation::table1_decode(fast)?);
    Ok(())
}
