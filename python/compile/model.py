"""L2: the MoE transformer forward pass as composable JAX ops.

Each function here is one AOT unit: ``aot.py`` lowers it (per token bucket /
precision / model variant) to HLO text that the rust runtime loads and
executes. The rust coordinator owns control flow *between* ops — routing
dispatch, expert gather/scatter, residual combine across the MoE experts, the
KV cache, layer iteration — so that expert precision can change at runtime
without recompiling anything.

Conventions:
* all activations are f32 (the "fp16 tier" executes as f32 on the CPU PJRT
  plugin; tier semantics, not IEEE format, are what the paper's mechanism
  needs — see DESIGN.md §2);
* every op takes its weights as arguments (nothing is baked into the HLO), so
  one executable serves all layers/experts of a given shape;
* ops return tuples (lowered with ``return_tuple=True``; rust unwraps).
"""

import jax
import jax.numpy as jnp

from . import configs
from .kernels import fmatmul, qmatmul

# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def rmsnorm(x, g, eps=1e-6):
    """RMSNorm over the last axis with learned gain ``g``."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


# --------------------------------------------------------------------------
# AOT ops
# --------------------------------------------------------------------------


def embed(tokens, table):
    """tokens i32[T] → hidden f32[T, D] (table f32[V, D])."""
    return (jnp.take(table, tokens, axis=0),)


def block_attn_prefill(x, g, wq, wk, wv, wo):
    """Pre-norm causal MHA over a full prompt.

    x f32[T, D] → (x + attn_out f32[T, D], k f32[T, D], v f32[T, D]).
    k/v are returned flat so rust can place them into the KV cache.
    """
    t, d = x.shape
    h, hd = configs.N_HEADS, configs.HEAD_DIM
    xn = rmsnorm(x, g)
    q = (xn @ wq).reshape(t, h, hd)
    k = xn @ wk
    v = xn @ wv
    kh = k.reshape(t, h, hd)
    vh = v.reshape(t, h, hd)
    scores = jnp.einsum("thd,shd->hts", q, kh) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,shd->thd", probs, vh).reshape(t, d) @ wo
    return (x + out, k, v)


def block_attn_decode(x, g, wq, wk, wv, wo, k_cache, v_cache, pos):
    """Pre-norm MHA for one decode step over a batch.

    x f32[B, D]; k_cache/v_cache f32[B, S, D]; pos i32[B] (#valid rows, i.e.
    the slot this token writes). Returns (x + out, k_cache', v_cache').
    """
    b, d = x.shape
    s = k_cache.shape[1]
    h, hd = configs.N_HEADS, configs.HEAD_DIM
    xn = rmsnorm(x, g)
    q = (xn @ wq).reshape(b, h, hd)
    k_new = xn @ wk  # [B, D]
    v_new = xn @ wv

    def upd(cache, new, p):
        return jax.lax.dynamic_update_slice(cache, new[None, :], (p, 0))

    k_cache = jax.vmap(upd)(k_cache, k_new, pos)
    v_cache = jax.vmap(upd)(v_cache, v_new, pos)

    kh = k_cache.reshape(b, s, h, hd)
    vh = v_cache.reshape(b, s, h, hd)
    scores = jnp.einsum("bhd,bshd->bhs", q, kh) / jnp.sqrt(float(hd))
    valid = jnp.arange(s)[None, :] <= pos[:, None]  # [B, S]
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vh).reshape(b, d) @ wo
    return (x + out, k_cache, v_cache)


def _topk_iterative(logits, k):
    """Top-k via k rounds of argmax + mask.

    ``jax.lax.top_k`` lowers to a dedicated `topk(..., largest=true)` HLO
    instruction that the xla crate's HLO-text parser (xla_extension 0.5.1)
    rejects; iterative argmax lowers to plain reduce/select ops that
    round-trip cleanly. k ≤ 10 and E ≤ 512 here, so the unrolled loop is
    cheap. Ties resolve to the lowest index, like lax.top_k.
    """
    t, e = logits.shape
    iota = jnp.arange(e)[None, :]
    x = logits
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)              # [T]
        v = jnp.max(x, axis=-1)                 # [T]
        vals.append(v)
        idxs.append(i)
        x = jnp.where(iota == i[:, None], -jnp.inf, x)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def moe_router(x, g, wr, *, top_k):
    """Pre-norm router: x f32[T, D], wr f32[D, E] →
    (xn f32[T, D], idx i32[T, k], weights f32[T, k]).

    ``xn`` is the normalized expert input; rust gathers its rows per selected
    expert, invokes the per-precision expert executable, and scatter-adds
    ``weights``-scaled outputs back onto the residual ``x``.
    """
    xn = rmsnorm(x, g)
    logits = xn @ wr
    vals, idx = _topk_iterative(logits, top_k)
    w = jax.nn.softmax(vals, axis=-1)
    return (xn, idx.astype(jnp.int32), w)


def expert_ffn_fp16(x, w1, w3, w2):
    """Full-precision SwiGLU expert: f32[T, D] → f32[T, D] (L1 fmatmul)."""
    h1 = fmatmul(x, w1)
    h3 = fmatmul(x, w3)
    h = jax.nn.silu(h1) * h3
    return (fmatmul(h, w2),)


def expert_ffn_quant(x, w1p, s1, w3p, s3, w2p, s2, *, bits):
    """Quantized SwiGLU expert via the L1 fused dequant-GEMM kernel."""
    h1 = qmatmul(x, w1p, s1, bits=bits)
    h3 = qmatmul(x, w3p, s3, bits=bits)
    h = jax.nn.silu(h1) * h3
    return (qmatmul(h, w2p, s2, bits=bits),)


def lm_head(x, g, wout):
    """Final norm + projection to logits: f32[T, D] → f32[T, V]."""
    return (rmsnorm(x, g) @ wout,)


# --------------------------------------------------------------------------
# Whole-model reference (tests + quality oracle; never exported)
# --------------------------------------------------------------------------


def reference_forward(params, tokens, *, top_k, bits_per_expert=None):
    """Pure-jnp single-sequence forward used by python tests as the oracle
    for the rust engine's layer orchestration.

    ``params`` matches the weight layout produced by tests/helpers;
    ``bits_per_expert[layer][e]`` optionally selects 16/4/2 per expert
    (mirroring what VER does at runtime).
    """
    from . import quant as qt
    import numpy as np

    x = jnp.take(params["embed"], tokens, axis=0)
    n_layers = len(params["layers"])
    for li in range(n_layers):
        lp = params["layers"][li]
        x, _, _ = block_attn_prefill(
            x, lp["attn_g"], lp["wq"], lp["wk"], lp["wv"], lp["wo"]
        )
        xn, idx, w = moe_router(x, lp["moe_g"], lp["wr"], top_k=top_k)
        t = x.shape[0]
        y = jnp.zeros_like(x)
        for ti in range(t):
            acc = jnp.zeros((x.shape[1],), dtype=jnp.float32)
            for kk in range(top_k):
                e = int(idx[ti, kk])
                ew = lp["experts"][e]
                bits = 16
                if bits_per_expert is not None:
                    bits = bits_per_expert[li][e]
                if bits == 16:
                    (out,) = expert_ffn_fp16(
                        xn[ti : ti + 1], ew["w1"], ew["w3"], ew["w2"]
                    )
                else:
                    packed = {
                        m: qt.quantize(np.asarray(ew[m]), bits)
                        for m in ("w1", "w3", "w2")
                    }
                    (out,) = expert_ffn_quant(
                        xn[ti : ti + 1],
                        packed["w1"][0], packed["w1"][1],
                        packed["w3"][0], packed["w3"][1],
                        packed["w2"][0], packed["w2"][1],
                        bits=bits,
                    )
                acc = acc + w[ti, kk] * out[0]
            for se in lp.get("shared", []):
                (out,) = expert_ffn_fp16(
                    xn[ti : ti + 1], se["w1"], se["w3"], se["w2"]
                )
                acc = acc + out[0]
            y = y.at[ti].set(acc)
        x = x + y
    (logits,) = lm_head(x, params["final_g"], params["wout"])
    return logits
