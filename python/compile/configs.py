"""Model/core dimension presets shared by the AOT pipeline and tests.

The three simulated models reproduce the *routing-relevant* structure of the
paper's evaluation targets (Table 3): expert count, top-k, shared experts.
Core tensor dims are shared across models so that the expensive executables
(attention, expert FFN, embed, lm_head) compile once and are reused; only the
router (whose shape depends on the expert count / top-k) is per-model.
"""

from dataclasses import dataclass, field


# --- Core dims shared by every simulated model -----------------------------
D_MODEL = 64          # hidden size
N_HEADS = 4
HEAD_DIM = D_MODEL // N_HEADS
FF_DIM = 128          # per-expert FFN dim
VOCAB = 256           # byte-level tokenizer
S_MAX = 512           # KV-cache capacity per sequence (decode executables)

# Token-count buckets. Ops that consume a flat token axis compile once per
# bucket; the rust runtime pads to the next bucket.
TOKEN_BUCKETS = (1, 4, 16, 64, 256)
# Batch buckets for the decode-step attention executable.
BATCH_BUCKETS = (1, 4, 8)
# Token buckets for the per-expert FFN (tokens gathered for one expert).
EXPERT_TOKEN_BUCKETS = (1, 4, 16, 64)


@dataclass(frozen=True)
class ModelPreset:
    """Routing structure of one simulated MoE model (paper Table 3)."""

    name: str
    n_layers: int           # executed layers in this reproduction
    n_experts: int          # experts per MoE layer
    top_k: int
    n_shared: int           # always-on shared experts per layer
    hi_bits: int            # precision of the "hot" tier (16 == fp)
    lo_bits: int            # precision of the "cold" tier
    paper_layers: int = 0   # layer count of the paper's real model (metadata)

    @property
    def router_key(self) -> str:
        return f"e{self.n_experts}k{self.top_k}"


PRESETS = {
    # Qwen3-30B-A3B: 48 layers, 128 experts, top-8, hot=FP16 / cold=INT4
    "qwen30b-sim": ModelPreset(
        name="qwen30b-sim", n_layers=4, n_experts=128, top_k=8,
        n_shared=0, hi_bits=16, lo_bits=4, paper_layers=48,
    ),
    # Qwen3-Next-80B: 48 layers, 512 experts, top-10, 1 shared,
    # hot=INT4 / cold=INT2 (the paper serves the 80B model from an Int4 base)
    "qwen80b-sim": ModelPreset(
        name="qwen80b-sim", n_layers=4, n_experts=512, top_k=10,
        n_shared=1, hi_bits=4, lo_bits=2, paper_layers=48,
    ),
    # Phi-3.5-MoE: 32 layers, 16 experts, top-2, hot=FP16 / cold=INT4
    "phi-sim": ModelPreset(
        name="phi-sim", n_layers=4, n_experts=16, top_k=2,
        n_shared=0, hi_bits=16, lo_bits=4, paper_layers=32,
    ),
}


def bits_name(bits: int) -> str:
    return "fp16" if bits == 16 else f"int{bits}"
