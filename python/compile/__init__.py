"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT export.

Nothing in this package is imported at serving time; ``make artifacts`` runs
``python -m compile.aot`` once and the rust coordinator consumes only the
emitted HLO text + manifest.
"""
