"""L1 Pallas kernels: the mixed-precision expert GEMM hot spot.

The paper's hot path is a grouped, mixed-precision expert GEMM on CUDA
(dequantize int4/int2 tiles into shared memory, feed tensor cores). The TPU
rethink (DESIGN.md §3):

* packed sub-byte weight tiles stream HBM→VMEM via the BlockSpec grid — the
  analogue of threadblock tiling over PCIe/HBM;
* the kernel unpacks a ``(block_k/pack, block_n)`` packed tile into a
  ``(block_k, block_n)`` f32 tile *in VMEM*, applies per-output-channel
  scales, and feeds the MXU with an f32-accumulating ``jnp.dot``
  (``preferred_element_type``) — the analogue of dequant-into-shared-memory
  + WMMA;
* ``block_n`` is kept a multiple of the 128-lane MXU dimension when the
  problem is large enough.

Kernels are lowered with ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is both the correctness
path and what ships in the AOT artifacts (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Packing contract — must match quant.py and rust model/quant.rs.
# int2 uses half-integer levels (bias 1.5): {-1.5,-0.5,0.5,1.5}·scale.
_BIAS = {4: 8.0, 2: 1.5}
_PACK = {4: 2, 2: 4}

# MXU-friendly default tile for the output-channel axis.
DEFAULT_BLOCK_N = 128


def _unpack_tile(wp, bits):
    """Unpack a packed uint8[K/pack, BN] tile → f32[K, BN] (bias removed).

    Unpacking happens in VMEM on the already-staged tile; the interleave is
    expressed as stack+reshape, which Mosaic lowers to cheap lane shuffles.
    """
    pack, bias = _PACK[bits], _BIAS[bits]
    mask = (1 << bits) - 1
    parts = [((wp >> (bits * j)) & mask) for j in range(pack)]
    # parts[j][k] is logical row k*pack+j → interleave on a new axis 1.
    stacked = jnp.stack(parts, axis=1)  # [K/pack, pack, BN]
    kp, _, bn = stacked.shape
    return stacked.reshape(kp * pack, bn).astype(jnp.float32) - float(bias)


def _qmm_kernel(x_ref, wp_ref, s_ref, o_ref, *, bits):
    """One grid step: o[:, nb] = x @ dequant(wp[:, nb])."""
    x = x_ref[...]                      # [T, K]       (resident across grid)
    w = _unpack_tile(wp_ref[...], bits)  # [K, BN]     (streamed per step)
    w = w * s_ref[...][None, :]          # scale per output channel
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


def _mm_kernel(x_ref, w_ref, o_ref):
    """Full-precision tile matmul (the fp16-tier expert path)."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block_n(n: int) -> int:
    return n if n < DEFAULT_BLOCK_N else DEFAULT_BLOCK_N


@functools.partial(jax.jit, static_argnames=("bits",))
def qmatmul(x, w_packed, scales, *, bits):
    """``x[T, K] @ dequant(w_packed[K/pack, N], scales[N])`` → f32[T, N].

    The quantized-GEMM Pallas kernel: grid over output-channel blocks; the
    activation tile stays in VMEM, packed weight tiles stream in.
    """
    t, k = x.shape
    kp, n = w_packed.shape
    assert kp * _PACK[bits] == k, (kp, k, bits)
    bn = _pick_block_n(n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, k), lambda i: (0, 0)),          # x: resident
            pl.BlockSpec((kp, bn), lambda i: (0, i)),        # weights: stream
            pl.BlockSpec((bn,), lambda i: (i,)),             # scales
        ],
        out_specs=pl.BlockSpec((t, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(x, w_packed, scales)


@jax.jit
def fmatmul(x, w):
    """Full-precision Pallas tile matmul ``x[T, K] @ w[K, N]`` → f32[T, N]."""
    t, k = x.shape
    k2, n = w.shape
    assert k == k2
    bn = _pick_block_n(n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _mm_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((t, k), lambda i: (0, 0)),
            pl.BlockSpec((k, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((t, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_bytes(t: int, k: int, n: int, bits: int) -> int:
    """Estimated VMEM footprint of one grid step (perf analysis, DESIGN §7).

    activation tile + packed weight tile + unpacked f32 tile + scales + out.
    """
    bn = _pick_block_n(n)
    pack = _PACK.get(bits, 1)
    act = t * k * 4
    wpacked = (k // pack) * bn * (1 if bits != 16 else 4)
    wunpacked = k * bn * 4 if bits != 16 else 0
    return act + wpacked + wunpacked + bn * 4 + t * bn * 4
