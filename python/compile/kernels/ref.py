"""Pure-jnp oracles for the L1 kernels.

Every Pallas kernel in this package has a reference implementation here with
identical semantics; pytest (python/tests/test_kernels.py) asserts
``assert_allclose`` between kernel and oracle across a hypothesis sweep of
shapes and bit-widths.
"""

import jax.numpy as jnp

_BIAS = {4: 8.0, 2: 1.5}
_PACK = {4: 2, 2: 4}


def unpack_ref(w_packed, bits):
    """Unpack uint8[K/pack, N] → f32[K, N] with the bias removed."""
    pack, bias = _PACK[bits], _BIAS[bits]
    mask = (1 << bits) - 1
    kp, n = w_packed.shape
    parts = [
        ((w_packed >> (bits * j)) & mask).astype(jnp.float32) - float(bias)
        for j in range(pack)
    ]
    return jnp.stack(parts, axis=1).reshape(kp * pack, n)


def dequant_ref(w_packed, scales, bits):
    """f32[K, N] ≈ original weights."""
    return unpack_ref(w_packed, bits) * scales[None, :]


def qmatmul_ref(x, w_packed, scales, *, bits):
    """Oracle for kernels.moe_gemm.qmatmul."""
    return jnp.dot(
        x, dequant_ref(w_packed, scales, bits),
        preferred_element_type=jnp.float32,
    )


def fmatmul_ref(x, w):
    """Oracle for kernels.moe_gemm.fmatmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
