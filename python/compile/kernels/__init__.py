"""L1: Pallas kernels for the mixed-precision expert GEMM hot spot."""

from .moe_gemm import fmatmul, qmatmul, vmem_bytes  # noqa: F401
