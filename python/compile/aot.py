"""AOT export: lower every L2 op × (token bucket, precision, model variant)
to HLO **text** + a manifest the rust runtime parses.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts``  (from python/)
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_units():
    """Yield (name, fn, arg_specs, meta) for every AOT unit."""
    D, F, V, S = configs.D_MODEL, configs.FF_DIM, configs.VOCAB, configs.S_MAX
    units = []

    for t in configs.TOKEN_BUCKETS:
        units.append((
            f"embed_t{t}",
            model.embed,
            [spec((t,), I32), spec((V, D))],
            {"op": "embed", "tokens": t},
        ))
        units.append((
            f"lm_head_t{t}",
            model.lm_head,
            [spec((t, D)), spec((D,)), spec((D, V))],
            {"op": "lm_head", "tokens": t},
        ))

    for t in configs.TOKEN_BUCKETS:
        if t < 4:
            continue  # prefill prompts are ≥4 tokens
        units.append((
            f"attn_prefill_t{t}",
            model.block_attn_prefill,
            [spec((t, D)), spec((D,))] + [spec((D, D))] * 4,
            {"op": "attn_prefill", "tokens": t},
        ))

    for b in configs.BATCH_BUCKETS:
        units.append((
            f"attn_decode_b{b}",
            model.block_attn_decode,
            [spec((b, D)), spec((D,))] + [spec((D, D))] * 4
            + [spec((b, S, D)), spec((b, S, D)), spec((b,), I32)],
            {"op": "attn_decode", "batch": b, "s_max": S},
        ))

    for preset in configs.PRESETS.values():
        e, k = preset.n_experts, preset.top_k
        def mk_router(k=k):
            def fn(x, g, wr):
                return model.moe_router(x, g, wr, top_k=k)
            return fn
        for t in configs.TOKEN_BUCKETS:
            name = f"router_{preset.router_key}_t{t}"
            if any(u[0] == name for u in units):
                continue  # two presets may share a router shape
            units.append((
                name,
                mk_router(),
                [spec((t, D)), spec((D,)), spec((D, e))],
                {"op": "router", "tokens": t, "experts": e, "top_k": k},
            ))

    for t in configs.EXPERT_TOKEN_BUCKETS:
        units.append((
            f"expert_fp16_t{t}",
            model.expert_ffn_fp16,
            [spec((t, D)), spec((D, F)), spec((D, F)), spec((F, D))],
            {"op": "expert_ffn", "tokens": t, "precision": "fp16"},
        ))
        for bits in (4, 2):
            pack = 2 if bits == 4 else 4
            def mk_q(bits=bits):
                def fn(x, w1p, s1, w3p, s3, w2p, s2):
                    return model.expert_ffn_quant(
                        x, w1p, s1, w3p, s3, w2p, s2, bits=bits
                    )
                return fn
            units.append((
                f"expert_int{bits}_t{t}",
                mk_q(),
                [
                    spec((t, D)),
                    spec((D // pack, F), jnp.uint8), spec((F,)),
                    spec((D // pack, F), jnp.uint8), spec((F,)),
                    spec((F // pack, D), jnp.uint8), spec((D,)),
                ],
                {"op": "expert_ffn", "tokens": t, "precision": f"int{bits}"},
            ))
    return units


def source_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` no-op."""
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="emit only units whose name contains this substring")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    fp = source_fingerprint()
    stamp = os.path.join(args.out, "fingerprint.txt")
    if args.only is None and os.path.exists(stamp):
        with open(stamp) as fh:
            if fh.read().strip() == fp:
                print(f"artifacts up to date (fingerprint {fp})")
                return 0

    units = build_units()
    manifest = [
        "#dims\td={} f={} v={} s_max={} heads={}".format(
            configs.D_MODEL, configs.FF_DIM, configs.VOCAB,
            configs.S_MAX, configs.N_HEADS,
        )
    ]
    for name, fn, arg_specs, meta in units:
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as fh:
            fh.write(text)
        kv = ";".join(f"{k}={v}" for k, v in sorted(meta.items()))
        manifest.append(f"{name}\t{fname}\t{kv}")
        print(f"  lowered {name} ({len(text)} chars)")
    if args.only is None:
        with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
            fh.write("\n".join(manifest) + "\n")
        write_quant_golden(args.out)
        with open(stamp, "w") as fh:
            fh.write(fp + "\n")
        print(f"wrote {len(units)} units + manifest to {args.out}")
    return 0


def golden_matrix(k: int, n: int):
    """Deterministic test matrix computed identically in python and rust
    (integer Weyl sequence → [-1, 1) f32); see rust/tests/quant_golden.rs."""
    import numpy as np

    idx = np.arange(k * n, dtype=np.uint64)
    h = (idx * np.uint64(2654435761)) % np.uint64(2**32)
    w = (h.astype(np.float64) / 2**31) - 1.0
    return w.astype(np.float32).reshape(k, n)


def write_quant_golden(out_dir: str) -> None:
    """Cross-language golden file: packed int4/int2 + scales of the golden
    matrix. rust's model::quant must reproduce it bit-exactly."""
    from . import quant

    w = golden_matrix(64, 16)
    with open(os.path.join(out_dir, "quant_golden.bin"), "wb") as fh:
        for bits in (4, 2):
            packed, scales = quant.quantize(w, bits)
            fh.write(packed.tobytes())
            fh.write(scales.astype("<f4").tobytes())


if __name__ == "__main__":
    sys.exit(main())
