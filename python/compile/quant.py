"""Symmetric per-output-channel weight quantization (int4 / int2) + sub-byte
packing.

The packed layout is the contract between the build-time weight preparation
(here, mirrored bit-exactly by rust `model/quant.rs`) and the L1 Pallas
dequant-GEMM kernel:

* weights ``W[K, N]`` are quantized per output channel ``n`` to the level set
  ``{(u - bias) * s : u = 0..2^bits-1}`` with ``s[n] = max|W[:, n]| / qmax``:
  - int4: integer levels, ``bias = 8``,  ``qmax = 7``  (q ∈ [-8, 7])
  - int2: **half-integer** levels, ``bias = 1.5``, ``qmax = 1.5``
    (levels {-1.5, -0.5, +0.5, +1.5}·s — symmetric, all four levels used;
    integer int2 levels waste one level and clip +absmax to absmax/2)
* stored codes ``u`` are unsigned values in ``[0, 2^bits - 1]``
* packing is along the **contraction axis K** (little-endian within a byte):
  int4 → byte ``b[k, n] = (u[2k+1, n] << 4) | u[2k, n]``
  int2 → byte ``b[k, n] = u[4k+3]<<6 | u[4k+2]<<4 | u[4k+1]<<2 | u[4k]``

Dequantization: ``W ≈ (u - bias) * s[n]``.
"""

import numpy as np

INT4 = dict(bits=4, pack=2, qmax=7.0, bias=8.0)
INT2 = dict(bits=2, pack=4, qmax=1.5, bias=1.5)


def spec(bits: int) -> dict:
    if bits == 4:
        return INT4
    if bits == 2:
        return INT2
    raise ValueError(f"unsupported bit-width {bits}")


def quantize(w: np.ndarray, bits: int):
    """Quantize ``w[K, N]`` → (packed uint8[K/pack, N], scales f32[N]).

    K must be divisible by the pack factor (2 for int4, 4 for int2).
    """
    s = spec(bits)
    w = np.asarray(w, dtype=np.float32)
    k, n = w.shape
    if k % s["pack"]:
        raise ValueError(f"K={k} not divisible by pack={s['pack']}")
    absmax = np.abs(w).max(axis=0)
    scales = np.where(absmax > 0, absmax / s["qmax"], 1.0).astype(np.float32)
    umax = (1 << s["bits"]) - 1
    u = np.clip(np.round(w / scales + s["bias"]), 0, umax).astype(np.uint8)
    packed = np.zeros((k // s["pack"], n), dtype=np.uint8)
    for j in range(s["pack"]):
        packed |= u[j :: s["pack"], :] << (s["bits"] * j)
    return packed, scales


def unpack(packed: np.ndarray, bits: int) -> np.ndarray:
    """Unpack uint8[K/pack, N] → f32[K, N] (bias removed, unscaled)."""
    s = spec(bits)
    kp, n = packed.shape
    out = np.zeros((kp * s["pack"], n), dtype=np.float32)
    mask = (1 << s["bits"]) - 1
    for j in range(s["pack"]):
        out[j :: s["pack"], :] = ((packed >> (s["bits"] * j)) & mask).astype(
            np.float32
        ) - s["bias"]
    return out


def dequantize(packed: np.ndarray, scales: np.ndarray, bits: int) -> np.ndarray:
    """Reconstruct f32[K, N] from a packed representation."""
    return unpack(packed, bits) * scales[None, :]


def quant_error(w: np.ndarray, bits: int) -> float:
    """Relative Frobenius reconstruction error (diagnostics / tests)."""
    packed, scales = quantize(w, bits)
    wq = dequantize(packed, scales, bits)
    denom = np.linalg.norm(w) or 1.0
    return float(np.linalg.norm(w - wq) / denom)
