"""L1 kernel performance analysis: VMEM footprint + MXU utilization
estimates for the Pallas dequant-GEMM at deployment (paper-scale) shapes.

Interpret-mode timings are CPU-numpy and not a TPU proxy (see the session
rules), so the perf pass analyses *structure*: per-grid-step VMEM residency
against the ~16 MB budget, arithmetic intensity against the bandwidth
roofline, and the dequant overhead of sub-byte tiles.

Run: ``python -m compile.perf_analysis`` (from python/). The numbers are
recorded in DESIGN.md §7 / EXPERIMENTS.md §Perf; pytest asserts the VMEM
budget invariants in tests/test_perf_analysis.py.
"""

from dataclasses import dataclass

# TPU-v4-class parameters used for the estimates (per core).
VMEM_BYTES = 16 * 2**20          # ~16 MB usable VMEM
HBM_BW = 1.2e12                  # ~1.2 TB/s
MXU_FLOPS = 137e12               # ~137 bf16 TFLOP/s


@dataclass(frozen=True)
class KernelConfig:
    """One qmatmul/fmatmul invocation shape."""

    name: str
    t: int        # activation rows
    k: int        # contraction dim
    n: int        # output channels
    bits: int     # 16 = full precision
    block_n: int  # output-channel tile

    @property
    def pack(self) -> int:
        return {16: 1, 4: 2, 2: 4}[self.bits]

    def vmem_step_bytes(self) -> int:
        """Per-grid-step VMEM residency.

        activation tile (resident) + packed weight tile (streamed) +
        unpacked f32 tile (scratch) + scales + output tile.
        """
        act = self.t * self.k * 4
        wpacked = (self.k // self.pack) * self.block_n * (
            4 if self.bits == 16 else 1
        )
        wunpacked = 0 if self.bits == 16 else self.k * self.block_n * 4
        scales = 0 if self.bits == 16 else self.block_n * 4
        out = self.t * self.block_n * 4
        return act + wpacked + wunpacked + scales + out

    def flops(self) -> float:
        return 2.0 * self.t * self.k * self.n

    def hbm_bytes(self) -> float:
        """HBM traffic: activation once, packed weights once, output once."""
        w_bytes = self.k * self.n * (2 if self.bits == 16 else 1 / self.pack)
        return self.t * self.k * 4 + w_bytes + self.t * self.n * 4

    def arithmetic_intensity(self) -> float:
        return self.flops() / self.hbm_bytes()

    def mxu_utilization_estimate(self) -> float:
        """Roofline estimate: achieved/peak FLOPs given HBM bandwidth.

        util = min(1, AI / (MXU_FLOPS / HBM_BW)) — the classic roofline
        ridge point; the MoE decode regime (small t) is bandwidth-bound,
        which is exactly why low-bit expert weights speed up decode.
        """
        ridge = MXU_FLOPS / HBM_BW
        return min(1.0, self.arithmetic_intensity() / ridge)

    def dequant_overhead_ops(self) -> float:
        """Extra elementwise ops per matmul FLOP for sub-byte unpack:
        shift+mask+sub+mul per weight element, amortized over 2·t FLOPs
        per element."""
        if self.bits == 16:
            return 0.0
        return 4.0 / (2.0 * self.t)


def deployment_configs():
    """Kernel shapes at the paper models' logical dims."""
    return [
        # qwen30b expert (d=2048, ff=768): decode (t=1..8) and prefill tiles
        KernelConfig("q30 w1 decode t1 int4", 1, 2048, 768, 4, 128),
        KernelConfig("q30 w1 decode t8 int4", 8, 2048, 768, 4, 128),
        KernelConfig("q30 w1 prefill t256 fp16", 256, 2048, 768, 16, 128),
        KernelConfig("q30 w1 prefill t256 int4", 256, 2048, 768, 4, 128),
        # qwen80b expert at int2
        KernelConfig("q80 w1 decode t1 int2", 1, 2048, 512, 2, 128),
        KernelConfig("q80 w1 prefill t256 int2", 256, 2048, 512, 2, 128),
        # phi expert (d=4096, ff=6400)
        KernelConfig("phi w1 decode t4 int4", 4, 4096, 6400, 4, 128),
        KernelConfig("phi w1 prefill t256 fp16", 256, 4096, 6400, 16, 128),
    ]


def report() -> str:
    lines = [
        f"{'config':<28} {'VMEM/step':>10} {'AI':>7} {'MXU util':>9} "
        f"{'dequant ovh':>12}"
    ]
    for c in deployment_configs():
        lines.append(
            f"{c.name:<28} {c.vmem_step_bytes() / 2**20:>8.2f}MB "
            f"{c.arithmetic_intensity():>7.1f} "
            f"{c.mxu_utilization_estimate():>8.1%} "
            f"{c.dequant_overhead_ops():>11.2f}x"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
