"""Perf-analysis invariants (DESIGN.md §7 L1 targets)."""

from compile.perf_analysis import (
    VMEM_BYTES,
    KernelConfig,
    deployment_configs,
    report,
)


def test_all_deployment_configs_fit_vmem():
    """Target: per-grid-step VMEM residency ≤ 16 MB for every shape."""
    for c in deployment_configs():
        assert c.vmem_step_bytes() <= VMEM_BYTES, c.name


def test_prefill_is_compute_bound_decode_bandwidth_bound():
    decode = KernelConfig("d", 1, 2048, 768, 4, 128)
    prefill = KernelConfig("p", 256, 2048, 768, 16, 128)
    assert decode.mxu_utilization_estimate() < 0.2
    assert prefill.mxu_utilization_estimate() > 0.5


def test_lower_bits_lower_hbm_traffic():
    fp = KernelConfig("f", 8, 2048, 768, 16, 128)
    i4 = KernelConfig("4", 8, 2048, 768, 4, 128)
    i2 = KernelConfig("2", 8, 2048, 768, 2, 128)
    assert i2.hbm_bytes() < i4.hbm_bytes() < fp.hbm_bytes()
    # and therefore higher roofline utilization in the decode regime
    assert i2.arithmetic_intensity() > fp.arithmetic_intensity()


def test_dequant_overhead_amortizes_with_tokens():
    t1 = KernelConfig("a", 1, 2048, 768, 4, 128)
    t64 = KernelConfig("b", 64, 2048, 768, 4, 128)
    assert t64.dequant_overhead_ops() < t1.dequant_overhead_ops()
    # at t=64 the unpack cost is ≤ 2·matmul-FLOPs target of DESIGN §7
    assert t64.dequant_overhead_ops() < 2.0


def test_report_renders():
    r = report()
    assert "MXU util" in r
    assert len(r.splitlines()) == len(deployment_configs()) + 1
