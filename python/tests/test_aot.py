"""AOT pipeline: unit inventory, HLO-text emission, manifest format."""

import os

import jax
import pytest

from compile import aot, configs


def test_unit_inventory_complete():
    units = aot.build_units()
    names = {u[0] for u in units}
    # every bucket × op the rust engine resolves must exist
    for t in configs.TOKEN_BUCKETS:
        assert f"embed_t{t}" in names
        assert f"lm_head_t{t}" in names
    for b in configs.BATCH_BUCKETS:
        assert f"attn_decode_b{b}" in names
    for t in configs.EXPERT_TOKEN_BUCKETS:
        for prec in ("fp16", "int4", "int2"):
            assert f"expert_{prec}_t{t}" in names
    for preset in configs.PRESETS.values():
        for t in configs.TOKEN_BUCKETS:
            assert f"router_{preset.router_key}_t{t}" in names
    # no duplicates
    assert len(names) == len(units)


def test_units_have_metadata():
    for name, _fn, _specs, meta in aot.build_units():
        assert "op" in meta, name
        assert meta["op"] in {
            "embed", "lm_head", "attn_prefill", "attn_decode", "router",
            "expert_ffn",
        }


@pytest.mark.parametrize("unit_name", ["expert_int4_t1", "router_e16k2_t1"])
def test_hlo_text_emission(unit_name):
    """Lower one unit and verify the HLO text is parseable-looking and
    contains no `topk` instruction (which xla_extension 0.5.1 rejects)."""
    units = {u[0]: u for u in aot.build_units()}
    name, fn, specs, _meta = units[unit_name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert " topk(" not in text, "lax.top_k leaked into the HLO"


def test_fingerprint_changes_with_source(tmp_path):
    fp1 = aot.source_fingerprint()
    assert len(fp1) == 16
    assert fp1 == aot.source_fingerprint(), "deterministic"


def test_artifacts_dir_matches_manifest():
    """If artifacts were built, every manifest entry's file must exist."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    with open(manifest) as fh:
        for line in fh:
            if line.startswith("#") or not line.strip():
                continue
            _name, fname, _kv = line.split("\t")
            assert os.path.exists(os.path.join(art, fname)), fname
