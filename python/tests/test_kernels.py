"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes, bit-widths and magnitudes; every case asserts
``assert_allclose`` between the interpret-mode Pallas kernel and ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import moe_gemm, ref


def rand_packed(rng, k, n, bits):
    w = rng.normal(size=(k, n)).astype(np.float32)
    packed, scales = quant.quantize(w, bits)
    return w, jnp.asarray(packed), jnp.asarray(scales)


@pytest.mark.parametrize("bits", [4, 2])
@pytest.mark.parametrize("t,k,n", [(1, 64, 128), (16, 64, 128), (4, 128, 64)])
def test_qmatmul_matches_ref(bits, t, k, n):
    rng = np.random.default_rng(bits * 100 + t)
    x = jnp.asarray(rng.normal(size=(t, k)).astype(np.float32))
    _, packed, scales = rand_packed(rng, k, n, bits)
    out = moe_gemm.qmatmul(x, packed, scales, bits=bits)
    exp = ref.qmatmul_ref(x, packed, scales, bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [4, 2])
def test_qmatmul_matches_numpy_dequant(bits):
    """Against an independent numpy reconstruction (not jnp ref)."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = rng.normal(size=(64, 128)).astype(np.float32)
    packed, scales = quant.quantize(w, bits)
    wq = quant.dequantize(packed, scales, bits)
    out = moe_gemm.qmatmul(
        jnp.asarray(x), jnp.asarray(packed), jnp.asarray(scales), bits=bits
    )
    np.testing.assert_allclose(np.asarray(out), x @ wq, rtol=1e-4, atol=1e-4)


def test_fmatmul_matches_ref():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    out = moe_gemm.fmatmul(x, w)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.fmatmul_ref(x, w)), rtol=1e-5, atol=1e-5
    )


def test_unpack_tile_matches_ref():
    rng = np.random.default_rng(5)
    for bits in (4, 2):
        _, packed, _ = rand_packed(rng, 32, 16, bits)
        a = moe_gemm._unpack_tile(packed, bits)
        b = ref.unpack_ref(packed, bits)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.sampled_from([4, 8, 64, 128]),
    n=st.sampled_from([8, 16, 64, 128, 256]),
    bits=st.sampled_from([4, 2]),
    amp=st.floats(0.01, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_property_sweep(t, k, n, bits, amp, seed):
    """Any bucket-compatible shape/scale: kernel ≡ oracle."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(t, k)) * amp).astype(np.float32))
    w = (rng.normal(size=(k, n)) * amp).astype(np.float32)
    packed, scales = quant.quantize(w, bits)
    out = moe_gemm.qmatmul(
        x, jnp.asarray(packed), jnp.asarray(scales), bits=bits
    )
    exp = ref.qmatmul_ref(
        x, jnp.asarray(packed), jnp.asarray(scales), bits=bits
    )
    # Pallas-interpret and jnp may reduce the contraction in different
    # orders; tolerance scales with the dot-product magnitude ~ amp²·√k.
    tol = 1e-5 * (amp * amp) * float(k)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), rtol=2e-4, atol=max(tol, 1e-5)
    )
    assert out.shape == (t, n)
    assert out.dtype == jnp.float32


def test_vmem_estimate_sane():
    """Perf-analysis helper: quantized tiles need less VMEM for weights."""
    v_fp = moe_gemm.vmem_bytes(64, 64, 128, 16)
    v_i4 = moe_gemm.vmem_bytes(64, 64, 128, 4)
    assert v_fp > 0 and v_i4 > 0
    # packed weight tile is 8x smaller, but the unpacked f32 tile dominates;
    # the estimate must include it (honest accounting)
    assert v_i4 >= 64 * 128 * 4
