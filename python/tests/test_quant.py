"""Quantization/packing contract tests (mirrored by rust model/quant.rs)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


@pytest.mark.parametrize("bits,pack", [(4, 2), (2, 4)])
def test_pack_unpack_roundtrip_codes(bits, pack):
    """Every level within ±qmax·s survives quantize→dequantize exactly.

    (int4's u=0 level sits at −8s, below −qmax·s = −7s; including it would
    shift the derived scale, so the symmetric level set is tested.)
    """
    s = 0.37
    spec = quant.spec(bits)
    umax = (1 << bits) - 1
    levels = np.array(
        [
            (u - spec["bias"]) * s
            for u in range(umax + 1)
            if abs(u - spec["bias"]) <= spec["qmax"]
        ],
        dtype=np.float32,
    )
    reps = -(-pack * 4 // len(levels))  # enough rows, divisible by pack
    w = np.tile(levels, reps)[: len(levels) * reps, None].astype(np.float32)
    k = (w.shape[0] // pack) * pack
    w = w[:k]
    packed, scales = quant.quantize(w, bits)
    wq = quant.dequantize(packed, scales, bits)
    np.testing.assert_allclose(wq, w, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(scales, [s], rtol=1e-6)


def test_int4_known_bytes():
    """Pin the little-endian nibble layout (mirrored in rust quant.rs)."""
    # K=2, N=1: w = [-7s, 7s] → absmax 7s → scale s;
    # u = [round(-7+8), round(7+8)] = [1, 15] → byte = 15<<4 | 1 = 0xF1
    s = 0.5
    w = np.array([[-7 * s], [7 * s]], dtype=np.float32)
    packed, scales = quant.quantize(w, 4)
    assert packed.shape == (1, 1)
    assert packed[0, 0] == 0xF1
    np.testing.assert_allclose(scales, [s], rtol=1e-6)


def test_int2_known_bytes():
    """int2 half-integer levels: u ∈ {0..3}, 4 codes per byte."""
    s = 1.0
    w = np.array([[-1.5 * s], [-0.5 * s], [0.5 * s], [1.5 * s]], np.float32)
    packed, scales = quant.quantize(w, 2)
    assert packed.shape == (1, 1)
    # u = [0,1,2,3] little-endian → 3<<6 | 2<<4 | 1<<2 | 0 = 0xE4
    assert packed[0, 0] == 0xE4
    np.testing.assert_allclose(scales, [s], rtol=1e-6)


def test_zero_column_scale_is_one():
    w = np.zeros((8, 3), dtype=np.float32)
    packed, scales = quant.quantize(w, 4)
    np.testing.assert_allclose(scales, 1.0)
    np.testing.assert_allclose(quant.dequantize(packed, scales, 4), 0.0)


@pytest.mark.parametrize("bits", [4, 2])
def test_error_bounded_by_half_step(bits):
    """|w - wq| ≤ scale/2 per element (except clipping, which absmax scaling
    avoids for int4; int2's half-integer levels also avoid it)."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    packed, scales = quant.quantize(w, bits)
    wq = quant.dequantize(packed, scales, bits)
    assert np.all(np.abs(w - wq) <= scales[None, :] * 0.5 + 1e-6)


@pytest.mark.parametrize("bits", [4, 2])
def test_int4_better_than_int2(bits):
    rng = np.random.default_rng(3)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    assert quant.quant_error(w, 4) < quant.quant_error(w, 2)


@settings(max_examples=40, deadline=None)
@given(
    k=st.sampled_from([4, 8, 16, 64, 128]),
    n=st.integers(1, 32),
    bits=st.sampled_from([4, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_error_bound_property(k, n, bits, seed):
    """Property: reconstruction error ≤ half a quantization step, any shape."""
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(k, n)) * rng.uniform(0.01, 10)).astype(np.float32)
    packed, scales = quant.quantize(w, bits)
    assert packed.dtype == np.uint8
    assert packed.shape == (k // quant.spec(bits)["pack"], n)
    wq = quant.dequantize(packed, scales, bits)
    assert np.all(np.abs(w - wq) <= scales[None, :] * 0.5 + 1e-5)


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([8, 16, 32]),
    bits=st.sampled_from([4, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_deterministic(k, bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, 5)).astype(np.float32)
    p1, s1 = quant.quantize(w, bits)
    p2, s2 = quant.quantize(w.copy(), bits)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(s1, s2)


def test_bad_bits_rejected():
    with pytest.raises(ValueError):
        quant.spec(3)


def test_bad_k_rejected():
    with pytest.raises(ValueError):
        quant.quantize(np.zeros((3, 2), np.float32), 4)
