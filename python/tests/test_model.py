"""L2 model ops: shapes, semantics, and cross-op consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, quant

D = configs.D_MODEL
F = configs.FF_DIM
V = configs.VOCAB
S = configs.S_MAX


def rng_arrays(seed, *shapes):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.normal(size=s).astype(np.float32) / np.sqrt(s[-1]))
        for s in shapes
    ]


def test_embed_gathers():
    table = jnp.arange(V * D, dtype=jnp.float32).reshape(V, D)
    (x,) = model.embed(jnp.asarray([3, 0, 3], dtype=jnp.int32), table)
    assert x.shape == (3, D)
    np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(table[3]))
    np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(x[2]))


def test_rmsnorm_unit_scale():
    x = jnp.ones((2, D)) * 5.0
    g = jnp.ones(D)
    out = model.rmsnorm(x, g)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)


def test_attn_prefill_causality():
    """Changing a later token must not affect earlier outputs."""
    t = 8
    g = jnp.ones(D)
    wq, wk, wv, wo = rng_arrays(1, (D, D), (D, D), (D, D), (D, D))
    x1, = rng_arrays(2, (t, D))
    x2 = x1.at[t - 1].set(x1[t - 1] + 1.0)
    (o1, k1, v1) = model.block_attn_prefill(x1, g, wq, wk, wv, wo)
    (o2, _, _) = model.block_attn_prefill(x2, g, wq, wk, wv, wo)
    np.testing.assert_allclose(
        np.asarray(o1[: t - 1]), np.asarray(o2[: t - 1]), rtol=1e-5, atol=1e-6
    )
    assert k1.shape == (t, D)
    assert v1.shape == (t, D)
    # the perturbed position must differ
    assert not np.allclose(np.asarray(o1[t - 1]), np.asarray(o2[t - 1]))


def test_attn_decode_matches_prefill():
    """Decoding token t with a cache of tokens 0..t-1 must equal the t-th
    row of a full prefill — the KV-cache contract the rust engine relies on."""
    t = 6
    g = jnp.ones(D)
    wq, wk, wv, wo = rng_arrays(3, (D, D), (D, D), (D, D), (D, D))
    x, = rng_arrays(4, (t, D))
    (o_pre, k_pre, v_pre) = model.block_attn_prefill(x, g, wq, wk, wv, wo)

    # decode the last token against the cached first t-1
    k_cache = jnp.zeros((1, S, D)).at[0, : t - 1].set(k_pre[: t - 1])
    v_cache = jnp.zeros((1, S, D)).at[0, : t - 1].set(v_pre[: t - 1])
    pos = jnp.asarray([t - 1], dtype=jnp.int32)
    (o_dec, k2, v2) = model.block_attn_decode(
        x[t - 1 : t], g, wq, wk, wv, wo, k_cache, v_cache, pos
    )
    np.testing.assert_allclose(
        np.asarray(o_dec[0]), np.asarray(o_pre[t - 1]), rtol=1e-4, atol=1e-5
    )
    # the decode step must have written k/v of the new token at position t-1
    np.testing.assert_allclose(
        np.asarray(k2[0, t - 1]), np.asarray(k_pre[t - 1]), rtol=1e-4, atol=1e-5
    )


def test_router_topk_semantics():
    t, e, k = 4, 16, 3
    g = jnp.ones(D)
    x, = rng_arrays(5, (t, D))
    wr = jnp.zeros((D, e)).at[:, 5].set(1.0).at[:, 9].set(0.6).at[:, 2].set(0.3)
    xn, idx, w = model.moe_router(x, g, wr, top_k=k)
    assert xn.shape == (t, D)
    assert idx.shape == (t, k)
    assert w.shape == (t, k)
    np.testing.assert_allclose(np.asarray(w.sum(axis=-1)), 1.0, rtol=1e-5)
    # weights sorted descending (vals from iterative argmax)
    assert np.all(np.diff(np.asarray(w), axis=-1) <= 1e-6)


def test_router_iterative_topk_equals_lax_topk():
    rng = np.random.default_rng(11)
    logits = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    vals, idx = model._topk_iterative(logits, 5)
    lv, li = jax.lax.top_k(logits, 5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(lv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(li))


def test_expert_ffn_quant_close_to_fp():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    w1 = rng.normal(size=(D, F)).astype(np.float32) * 0.2
    w3 = rng.normal(size=(D, F)).astype(np.float32) * 0.2
    w2 = rng.normal(size=(F, D)).astype(np.float32) * 0.2
    (y_fp,) = model.expert_ffn_fp16(
        x, jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2)
    )
    outs = {}
    for bits in (4, 2):
        q1 = quant.quantize(w1, bits)
        q3 = quant.quantize(w3, bits)
        q2 = quant.quantize(w2, bits)
        (y_q,) = model.expert_ffn_quant(
            x,
            jnp.asarray(q1[0]), jnp.asarray(q1[1]),
            jnp.asarray(q3[0]), jnp.asarray(q3[1]),
            jnp.asarray(q2[0]), jnp.asarray(q2[1]),
            bits=bits,
        )
        rel = np.linalg.norm(np.asarray(y_q - y_fp)) / np.linalg.norm(
            np.asarray(y_fp)
        )
        outs[bits] = rel
    assert outs[4] < 0.35, f"int4 expert too far from fp: {outs[4]}"
    assert outs[4] < outs[2], "int4 must beat int2"


def test_lm_head_shape():
    x, = rng_arrays(17, (5, D))
    g = jnp.ones(D)
    wout, = rng_arrays(18, (D, V))
    (logits,) = model.lm_head(x, g, wout)
    assert logits.shape == (5, V)


@pytest.mark.slow
def test_reference_forward_runs():
    """Whole-model pure-jnp oracle (tiny config) executes and is finite."""
    rng = np.random.default_rng(23)
    n_experts, top_k, layers = 4, 2, 1
    mk = lambda *s: jnp.asarray(  # noqa: E731
        rng.normal(size=s).astype(np.float32) / np.sqrt(s[-1])
    )
    params = {
        "embed": mk(V, D),
        "final_g": jnp.ones(D),
        "wout": mk(D, V),
        "layers": [
            {
                "attn_g": jnp.ones(D),
                "wq": mk(D, D), "wk": mk(D, D), "wv": mk(D, D), "wo": mk(D, D),
                "moe_g": jnp.ones(D),
                "wr": mk(D, n_experts),
                "experts": [
                    {"w1": mk(D, F), "w3": mk(D, F), "w2": mk(F, D)}
                    for _ in range(n_experts)
                ],
            }
            for _ in range(layers)
        ],
    }
    tokens = jnp.asarray([1, 2, 3, 4], dtype=jnp.int32)
    logits = model.reference_forward(params, tokens, top_k=top_k)
    assert logits.shape == (4, V)
    assert bool(jnp.isfinite(logits).all())
    # mixed per-expert precision also runs
    bits = [[16, 4, 2, 16]]
    logits_q = model.reference_forward(
        params, tokens, top_k=top_k, bits_per_expert=bits
    )
    assert bool(jnp.isfinite(logits_q).all())
    assert not np.allclose(np.asarray(logits), np.asarray(logits_q))
