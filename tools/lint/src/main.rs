//! `dynaexq-lint`: the concurrency-conformance linter (DESIGN.md §16).
//!
//! A zero-dependency lexical scanner over `rust/src` that enforces the
//! invariants the type system cannot:
//!
//! * **raw-lock** — `std::sync::Mutex` / `RwLock` may only be named inside
//!   `util/lockorder.rs`; everything else goes through the ranked
//!   [`OrderedMutex`]/[`OrderedRwLock`] wrappers, so the lock-order audit
//!   cannot be bypassed by construction.
//! * **wall-clock** — `Instant` / `SystemTime` / `thread::sleep` are
//!   banned outside `bench/runtime.rs`: the simulated stack is driven by
//!   virtual time, and a stray wall-clock read silently breaks replay
//!   determinism.
//! * **hashmap-det** — modules that emit snapshots, traces, or kv text
//!   must use `BTreeMap`; `HashMap` iteration order would leak hash-seed
//!   nondeterminism into golden artifacts.
//! * **relaxed-ok** — every `Ordering::Relaxed` must carry a same-line
//!   `// relaxed-ok: <reason>` comment naming why relaxed suffices.
//!
//! The scanner strips comments, strings, and char literals before token
//! matching (same spirit as the serde-free `bench::json` parser), so prose
//! mentioning `Mutex` never fires. Intentional exceptions live in the
//! checked-in whitelist (`tools/lint/lint.allow`), one `<path-suffix>
//! <rule>` pair per line.
//!
//! Exit status: 0 when clean, 1 with findings, 2 on usage/IO errors.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules whose output must be byte-stable across runs (snapshot / kv /
/// trace emitters): `HashMap` is banned here, `BTreeMap` required.
const DETERMINISTIC_MODULES: &[&str] = &[
    "config/kv.rs",
    "serving/session.rs",
    "serving/backend.rs",
    "bench/json.rs",
    "workload/traces.rs",
    "metrics/mod.rs",
];

/// The one module allowed to name raw `std::sync` locks (it wraps them).
const LOCKORDER_MODULE: &str = "util/lockorder.rs";

/// The one module allowed to read wall-clock time (bench harness timing).
const WALLCLOCK_MODULE: &str = "bench/runtime.rs";

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Finding {
    path: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// One whitelist entry: suppresses `rule` findings in files whose
/// repo-relative path ends with `path_suffix`.
#[derive(Debug)]
struct Allow {
    path_suffix: String,
    rule: String,
    used: std::cell::Cell<bool>,
}

fn parse_allowlist(text: &str) -> Result<Vec<Allow>, String> {
    let mut out = Vec::new();
    for (n, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match (parts.next(), parts.next(), parts.next()) {
            (Some(p), Some(r), None) => out.push(Allow {
                path_suffix: p.to_string(),
                rule: r.to_string(),
                used: std::cell::Cell::new(false),
            }),
            _ => {
                return Err(format!(
                    "lint.allow line {}: expected `<path-suffix> <rule>`, \
                     got {line:?}",
                    n + 1
                ))
            }
        }
    }
    Ok(out)
}

/// Blank out comments, string/char literals, and raw strings, preserving
/// line structure (stripped chars become spaces, newlines survive), so
/// token matching only ever sees code.
fn strip_noncode(src: &str) -> String {
    let cs: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut prev_ident = false; // last emitted char was an identifier char
    let mut i = 0;
    let blank = |out: &mut String, c: char| {
        out.push(if c == '\n' { '\n' } else { ' ' })
    };
    while i < cs.len() {
        let c = cs[i];
        // line comment
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            while i < cs.len() && cs[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            prev_ident = false;
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 0;
            while i < cs.len() {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    blank(&mut out, cs[i]);
                    blank(&mut out, cs[i + 1]);
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    blank(&mut out, cs[i]);
                    blank(&mut out, cs[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut out, cs[i]);
                    i += 1;
                }
            }
            prev_ident = false;
            continue;
        }
        // raw (byte) string: r"..." / r#"..."# / br"..."
        if !prev_ident && (c == 'r' || (c == 'b' && cs.get(i + 1) == Some(&'r')))
        {
            let start = if c == 'b' { i + 2 } else { i + 1 };
            let mut hashes = 0;
            while cs.get(start + hashes) == Some(&'#') {
                hashes += 1;
            }
            if cs.get(start + hashes) == Some(&'"') {
                // emit the prefix as spaces, then skip to the terminator
                for &pc in &cs[i..start + hashes + 1] {
                    blank(&mut out, pc);
                }
                i = start + hashes + 1;
                'raw: while i < cs.len() {
                    if cs[i] == '"' {
                        let mut ok = true;
                        for h in 0..hashes {
                            if cs.get(i + 1 + h) != Some(&'#') {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            for k in 0..=hashes {
                                blank(&mut out, cs[i + k]);
                            }
                            i += hashes + 1;
                            break 'raw;
                        }
                    }
                    blank(&mut out, cs[i]);
                    i += 1;
                }
                prev_ident = false;
                continue;
            }
        }
        // ordinary (byte) string literal
        if c == '"' || (c == 'b' && cs.get(i + 1) == Some(&'"') && !prev_ident)
        {
            if c == 'b' {
                blank(&mut out, c);
                i += 1;
            }
            blank(&mut out, cs[i]); // opening quote
            i += 1;
            while i < cs.len() {
                if cs[i] == '\\' {
                    blank(&mut out, cs[i]);
                    if i + 1 < cs.len() {
                        blank(&mut out, cs[i + 1]);
                    }
                    i += 2;
                    continue;
                }
                let done = cs[i] == '"';
                blank(&mut out, cs[i]);
                i += 1;
                if done {
                    break;
                }
            }
            prev_ident = false;
            continue;
        }
        // char literal vs lifetime: 'x' / '\n' are literals; 'a in a type
        // position has no closing quote right after the name
        if c == '\'' {
            let is_literal = match cs.get(i + 1) {
                Some('\\') => true,
                Some(&n) if n != '\'' => cs.get(i + 2) == Some(&'\''),
                _ => false,
            };
            if is_literal {
                blank(&mut out, c);
                i += 1;
                while i < cs.len() {
                    if cs[i] == '\\' {
                        blank(&mut out, cs[i]);
                        if i + 1 < cs.len() {
                            blank(&mut out, cs[i + 1]);
                        }
                        i += 2;
                        continue;
                    }
                    let done = cs[i] == '\'';
                    blank(&mut out, cs[i]);
                    i += 1;
                    if done {
                        break;
                    }
                }
                prev_ident = false;
                continue;
            }
        }
        out.push(c);
        prev_ident = c.is_alphanumeric() || c == '_';
        i += 1;
    }
    out
}

/// Whether `tok` occurs in `line` as a whole token: the characters on
/// both sides (if any) are not identifier characters, so `Mutex` never
/// matches inside `OrderedMutex` or `MutexGuard`.
fn has_token(line: &str, tok: &str) -> bool {
    let bytes = line.as_bytes();
    let is_ident =
        |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0;
    while let Some(pos) = line[from..].find(tok) {
        let start = from + pos;
        let end = start + tok.len();
        let left_ok = start == 0 || !is_ident(bytes[start - 1]);
        let right_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

/// Scan one source file. `rel_path` is the repo-relative path with `/`
/// separators (rule applicability is decided by path suffix).
fn scan_file(rel_path: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let code = strip_noncode(src);
    let in_module = |m: &str| rel_path.ends_with(m);
    let deterministic =
        DETERMINISTIC_MODULES.iter().any(|m| in_module(m));
    for ((n, code_line), raw_line) in
        code.lines().enumerate().zip(src.lines())
    {
        let line = n + 1;
        let mut push = |rule: &'static str, msg: String| {
            findings.push(Finding {
                path: rel_path.to_string(),
                line,
                rule,
                msg,
            })
        };
        if !in_module(LOCKORDER_MODULE) {
            for tok in ["Mutex", "RwLock"] {
                if has_token(code_line, tok) {
                    push(
                        "raw-lock",
                        format!(
                            "raw std::sync::{tok} outside util::lockorder; \
                             use Ordered{tok} with a LockRank"
                        ),
                    );
                }
            }
        }
        if !in_module(WALLCLOCK_MODULE) {
            for tok in ["Instant", "SystemTime", "thread::sleep"] {
                if has_token(code_line, tok) {
                    push(
                        "wall-clock",
                        format!(
                            "{tok} outside bench::runtime breaks \
                             virtual-time determinism"
                        ),
                    );
                }
            }
        }
        if deterministic && has_token(code_line, "HashMap") {
            push(
                "hashmap-det",
                "HashMap in a snapshot/kv/trace module; use BTreeMap \
                 for stable iteration order"
                    .to_string(),
            );
        }
        if has_token(code_line, "Relaxed")
            && !code_line.trim_start().starts_with("use ")
            && !raw_line.contains("relaxed-ok:")
        {
            push(
                "relaxed-ok",
                "Ordering::Relaxed without a same-line \
                 `// relaxed-ok: <reason>` comment"
                    .to_string(),
            );
        }
    }
    findings
}

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn run(root: &Path, allow_path: &Path) -> Result<Vec<Finding>, String> {
    let allows = match fs::read_to_string(allow_path) {
        Ok(text) => parse_allowlist(&text)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(format!("reading {}: {e}", allow_path.display()))
        }
    };
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)
        .map_err(|e| format!("walking {}: {e}", src_root.display()))?;
    let mut findings = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(file)
            .map_err(|e| format!("reading {}: {e}", file.display()))?;
        for f in scan_file(&rel, &src) {
            let allowed = allows.iter().any(|a| {
                let hit = f.rule == a.rule
                    && f.path.ends_with(&a.path_suffix);
                if hit {
                    a.used.set(true);
                }
                hit
            });
            if !allowed {
                findings.push(f);
            }
        }
    }
    for a in &allows {
        if !a.used.get() {
            eprintln!(
                "warning: unused lint.allow entry `{} {}`",
                a.path_suffix, a.rule
            );
        }
    }
    findings.sort();
    Ok(findings)
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut allow: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => {
                    eprintln!("--root needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--allow" => match args.next() {
                Some(v) => allow = Some(PathBuf::from(v)),
                None => {
                    eprintln!("--allow needs a file");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!(
                    "usage: dynaexq-lint [--root DIR] [--allow FILE] \
                     (unknown arg {other:?})"
                );
                return ExitCode::from(2);
            }
        }
    }
    let allow = allow
        .unwrap_or_else(|| root.join("tools").join("lint").join("lint.allow"));
    match run(&root, &allow) {
        Ok(findings) if findings.is_empty() => {
            println!("dynaexq-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("dynaexq-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("dynaexq-lint: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("fixtures")
            .join(name);
        fs::read_to_string(&p)
            .unwrap_or_else(|e| panic!("fixture {}: {e}", p.display()))
    }

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn raw_lock_fires_outside_lockorder() {
        let src = fixture("raw_mutex.rs");
        let f = scan_file("rust/src/serving/somewhere.rs", &src);
        assert!(rules(&f).contains(&"raw-lock"), "{f:?}");
        // both Mutex and RwLock lines are caught
        assert_eq!(
            f.iter().filter(|x| x.rule == "raw-lock").count(),
            3,
            "{f:?}"
        );
    }

    #[test]
    fn raw_lock_allowed_inside_lockorder() {
        let src = fixture("raw_mutex.rs");
        let f = scan_file("rust/src/util/lockorder.rs", &src);
        assert!(!rules(&f).contains(&"raw-lock"), "{f:?}");
    }

    #[test]
    fn wall_clock_fires_outside_bench_runtime() {
        let src = fixture("wall_clock.rs");
        let f = scan_file("rust/src/coordinator/mod.rs", &src);
        assert_eq!(
            f.iter().filter(|x| x.rule == "wall-clock").count(),
            3,
            "{f:?}"
        );
        let f = scan_file("rust/src/bench/runtime.rs", &src);
        assert!(!rules(&f).contains(&"wall-clock"), "{f:?}");
    }

    #[test]
    fn hashmap_fires_only_in_deterministic_modules() {
        let src = fixture("hashmap_det.rs");
        let f = scan_file("rust/src/config/kv.rs", &src);
        assert!(rules(&f).contains(&"hashmap-det"), "{f:?}");
        let f = scan_file("rust/src/coordinator/mod.rs", &src);
        assert!(!rules(&f).contains(&"hashmap-det"), "{f:?}");
    }

    #[test]
    fn relaxed_requires_same_line_reason() {
        let src = fixture("relaxed_missing.rs");
        let f = scan_file("rust/src/coordinator/mod.rs", &src);
        // one bare Relaxed fires; the annotated one and the use-line don't
        assert_eq!(
            f.iter().filter(|x| x.rule == "relaxed-ok").count(),
            1,
            "{f:?}"
        );
    }

    #[test]
    fn clean_fixture_is_clean_everywhere() {
        let src = fixture("clean.rs");
        for path in [
            "rust/src/config/kv.rs",
            "rust/src/coordinator/mod.rs",
            "rust/src/serving/backend.rs",
        ] {
            let f = scan_file(path, &src);
            assert!(f.is_empty(), "{path}: {f:?}");
        }
    }

    #[test]
    fn tokens_in_comments_and_strings_are_ignored() {
        let src = r##"
//! Mutex in a doc comment, HashMap too, Instant::now().
// line comment: RwLock, Ordering::Relaxed
/* block /* nested */ Mutex */
fn f() -> &'static str {
    let _lifetime: Option<&'static str> = None;
    let _c = 'M';
    let s = "Mutex<HashMap> Instant Relaxed";
    let r = r#"SystemTime RwLock"#;
    s
}
"##;
        let f = scan_file("rust/src/config/kv.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn token_boundaries_exclude_wrappers() {
        let src = "type A = OrderedMutex<u8>;\n\
                   type B = MutexGuard<u8>;\n\
                   type C = OrderedRwLock<u8>;\n\
                   type D = RwLockReadGuard<u8>;\n";
        let f = scan_file("rust/src/serving/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn allowlist_suppresses_by_suffix_and_rule() {
        let allows =
            parse_allowlist("# comment\nbench/mod.rs wall-clock\n").unwrap();
        let f = Finding {
            path: "rust/src/bench/mod.rs".into(),
            line: 1,
            rule: "wall-clock",
            msg: String::new(),
        };
        assert!(allows
            .iter()
            .any(|a| f.rule == a.rule && f.path.ends_with(&a.path_suffix)));
        // same path, different rule: not suppressed
        assert!(!allows
            .iter()
            .any(|a| "raw-lock" == a.rule
                && f.path.ends_with(&a.path_suffix)));
    }

    #[test]
    fn allowlist_rejects_malformed_lines() {
        assert!(parse_allowlist("just-one-field\n").is_err());
        assert!(parse_allowlist("a b c\n").is_err());
        assert!(parse_allowlist("\n# only comments\n").unwrap().is_empty());
    }

    #[test]
    fn strip_preserves_line_numbers() {
        let src = "line1\n/* a\nb\nc */ Mutex::new(())\n";
        let f = scan_file("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4, "{f:?}");
    }

    #[test]
    fn whole_tree_is_clean() {
        // The real tree with the real whitelist: the CI contract.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("..");
        if !root.join("rust").join("src").is_dir() {
            return; // packaged standalone; nothing to scan
        }
        let allow = root.join("tools").join("lint").join("lint.allow");
        let findings = run(&root, &allow).unwrap();
        assert!(
            findings.is_empty(),
            "tree has unexempted findings:\n{}",
            findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
