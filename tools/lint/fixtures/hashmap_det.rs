//! Fixture: `HashMap` in a module (fires `hashmap-det` only when the
//! file path is one of the snapshot/kv/trace modules).

use std::collections::HashMap;

pub fn snapshot() -> HashMap<String, u64> {
    let mut m = HashMap::new();
    m.insert("k".to_string(), 1);
    m
}
