//! Fixture: raw `std::sync` lock construction (fires `raw-lock` three
//! times — two `Mutex` lines, one `RwLock` line). Mentioning Mutex here
//! in the doc comment must NOT fire.

pub struct Holder {
    slot: std::sync::Mutex<u32>,
}

pub fn build() -> Holder {
    let rw = std::sync::RwLock::new(0u32);
    let _ = rw.read();
    Holder { slot: std::sync::Mutex::new(7) }
}
