//! Fixture: conformant code — ranked lock wrappers, BTreeMap, annotated
//! Relaxed. Must produce zero findings under every module path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

pub struct State {
    counts: BTreeMap<String, u64>,
    hits: AtomicU64,
}

impl State {
    pub fn observe(&mut self, key: &str) {
        *self.counts.entry(key.to_string()).or_insert(0) += 1;
        self.hits.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
    }
}
