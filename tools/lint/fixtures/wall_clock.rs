//! Fixture: wall-clock reads (fires `wall-clock` three times — Instant,
//! SystemTime, thread::sleep — everywhere except bench/runtime.rs).

pub fn toll() -> u128 {
    let t0 = std::time::Instant::now();
    std::thread::sleep(std::time::Duration::from_millis(1));
    let _ = std::time::SystemTime::UNIX_EPOCH;
    t0.elapsed().as_nanos()
}
