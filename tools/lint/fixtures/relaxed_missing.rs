//! Fixture: `Ordering::Relaxed` without a same-line reason (fires
//! `relaxed-ok` exactly once — the import line and the annotated line
//! are exempt).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

pub fn bump(c: &AtomicU64, d: &AtomicU64) {
    c.fetch_add(1, Relaxed);
    d.fetch_add(1, Relaxed); // relaxed-ok: stat counter
}
